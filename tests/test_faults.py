"""Fault tolerance: CRC32C, retry policy, reader error propagation, fsck
classification/repair, writer crash-window resume, and bit-exact
kill-and-resume of the distributed executor — every claim of DESIGN.md's
"Failure model", driven through the ``tests/faults.py`` injectors."""
import json
import os
import threading

import numpy as np
import pytest

import jax

from faults import (
    SimulatedCrash,
    corrupt_block,
    fail_nth_read,
    kill_after_round,
    orphan_block,
)
from repro.core import eclat, fimi
from repro.store import (
    BlockReader,
    ChecksumMismatchError,
    MissingBlockError,
    NO_RETRY,
    RetriesExhausted,
    RetryPolicy,
    StaleManifestError,
    StoreIntegrityError,
    StoreWriter,
    TruncatedBlockError,
    TxStore,
    crc32c,
    fsck,
)
from repro.store.checksum import crc32c_ref
from repro.store.reader import BlockReadError


def _random_dense(n_tx, n_items, seed, density=0.3):
    rng = np.random.default_rng(seed)
    return rng.random((n_tx, n_items)) < density


def _store_from_dense(tmp_path, dense, sizes, name="st"):
    assert sum(sizes) == dense.shape[0]
    w = StoreWriter(str(tmp_path / name), n_items=dense.shape[1],
                    block_tx=max(sizes) if sizes else 1)
    off = 0
    for sz in sizes:
        w.append_dense(dense[off:off + sz])
        off += sz
    return w.close()


def _fimi_params():
    return fimi.FimiParams(
        min_support_rel=0.1, n_db_sample=128, n_fi_sample=256,
        eclat=eclat.EclatConfig(max_out=1 << 14, max_stack=2048,
                                frontier_size=8),
    )


# ---------------------------------------------------------------------------
# CRC32C — the vectorized implementation against spec and reference
# ---------------------------------------------------------------------------


def test_crc32c_check_value():
    # RFC 3720 B.4: CRC32C("123456789") == 0xE3069283
    data = np.frombuffer(b"123456789", np.uint8)
    assert crc32c(data) == 0xE3069283
    assert crc32c_ref(data) == 0xE3069283


@pytest.mark.parametrize("n", [0, 1, 3, 7, 63, 64, 65, 255, 1024, 4097])
def test_crc32c_matches_bytewise_reference(n):
    rng = np.random.default_rng(n)
    data = rng.integers(0, 256, size=n, dtype=np.uint8)
    assert crc32c(data) == crc32c_ref(data)


def test_crc32c_uint32_payload_and_sensitivity():
    rng = np.random.default_rng(0)
    arr = rng.integers(0, 1 << 32, size=(16, 3), dtype=np.uint32)
    c0 = crc32c(arr)
    assert c0 == crc32c_ref(arr.view(np.uint8).reshape(-1))
    arr[7, 1] ^= np.uint32(1 << 13)  # single bit flip must change the CRC
    assert crc32c(arr) != c0


# ---------------------------------------------------------------------------
# Retry policy — deterministic schedule, injectable clock
# ---------------------------------------------------------------------------


def test_retry_survives_transient_fault_with_exact_schedule():
    slept = []
    pol = RetryPolicy(attempts=4, base_delay_s=0.01, backoff=3.0,
                      max_delay_s=0.05, sleep=slept.append,
                      clock=lambda: 0.0)
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("transient")
        return "ok"

    assert pol.call(flaky, describe="read") == "ok"
    assert calls["n"] == 3
    assert slept == [0.01, 0.03]          # base·backoff^k, no sleep on success


def test_retry_delay_is_capped():
    pol = RetryPolicy(base_delay_s=0.01, backoff=10.0, max_delay_s=0.25)
    assert [pol.delay(k) for k in range(4)] == [0.01, 0.1, 0.25, 0.25]


def test_retry_exhaustion_wraps_last_error():
    slept = []
    pol = RetryPolicy(attempts=3, base_delay_s=0.01, sleep=slept.append,
                      clock=lambda: 0.0)
    with pytest.raises(RetriesExhausted, match="pull block 7.*3 attempts"):
        pol.call(lambda: (_ for _ in ()).throw(OSError("gone")),
                 describe="pull block 7")
    assert len(slept) == 2                # attempts−1 sleeps, then give up
    try:
        pol.call(lambda: (_ for _ in ()).throw(OSError("gone")), describe="x")
    except RetriesExhausted as e:
        assert isinstance(e.__cause__, OSError)


def test_retry_never_retries_integrity_errors():
    slept = []
    pol = RetryPolicy(attempts=5, sleep=slept.append)

    def bad():
        raise ChecksumMismatchError("persistent fact about disk bytes")

    with pytest.raises(ChecksumMismatchError):
        pol.call(bad)
    assert slept == []                    # first throw propagates untouched


# ---------------------------------------------------------------------------
# BlockReader — worker-thread failures surface at the consumer, typed
# ---------------------------------------------------------------------------


def test_reader_survives_transient_read_fault(tmp_path):
    dense = _random_dense(96, 16, seed=1)
    s = _store_from_dense(tmp_path, dense, [32, 32, 32])
    slept = []
    rd = BlockReader(s, retry=RetryPolicy(attempts=3, base_delay_s=0.001,
                                          sleep=slept.append))
    with fail_nth_read(2, OSError, fail_count=2):
        n_rows = sum(n for _, _, _, n in rd.device_blocks())
    assert n_rows == 96                   # stream completed despite the fault
    assert len(slept) == 2                # block 1 needed both retries
    assert rd.read_attempts == 5          # 3 clean reads + 2 failed attempts


def test_reader_persistent_fault_raises_with_block_context(tmp_path):
    dense = _random_dense(96, 16, seed=2)
    s = _store_from_dense(tmp_path, dense, [32, 32, 32])
    before = threading.active_count()
    rd = BlockReader(s, retry=RetryPolicy(attempts=2, base_delay_s=0.0,
                                          sleep=lambda _: None))
    with fail_nth_read(2, OSError):
        with pytest.raises(RetriesExhausted, match=r"read block 1 .*block_0"):
            for _ in rd.device_blocks():
                pass
    assert threading.active_count() == before   # worker joined, not leaked


def test_reader_wraps_unexpected_worker_errors(tmp_path):
    dense = _random_dense(64, 16, seed=3)
    s = _store_from_dense(tmp_path, dense, [32, 32])
    rd = BlockReader(s, retry=NO_RETRY)
    with fail_nth_read(2, RuntimeError):     # not retryable, not typed
        with pytest.raises(BlockReadError, match=r"block 1 .*block_000001"):
            for _ in rd.device_blocks():
                pass


def test_reader_passes_integrity_errors_through_typed(tmp_path):
    dense = _random_dense(64, 16, seed=4)
    s = _store_from_dense(tmp_path, dense, [32, 32])
    corrupt_block(s.directory, 1, "bitflip")
    with pytest.raises(ChecksumMismatchError, match="block_000001"):
        for _ in BlockReader(TxStore.open(s.directory)).device_blocks():
            pass


# ---------------------------------------------------------------------------
# Corruption reaches the miner as a distinct, actionable error
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode,exc", [
    ("bitflip", ChecksumMismatchError),
    ("truncate", TruncatedBlockError),
    ("delete", MissingBlockError),
    ("stale", StaleManifestError),
])
def test_corruption_fails_mining_with_typed_error(tmp_path, mode, exc):
    dense = _random_dense(128, 16, seed=5)
    s = _store_from_dense(tmp_path, dense, [32, 32, 32, 32])
    corrupt_block(s.directory, 2, mode)
    s2 = TxStore.open(s.directory)        # manifest still loads fine
    with pytest.raises(exc, match="block_000002") as ei:
        fimi.run(s2, None, _fimi_params(), jax.random.PRNGKey(0),
                 materialize=True, P=2)
    assert isinstance(ei.value, StoreIntegrityError)   # one catchable base


def test_verify_off_skips_checksum_only(tmp_path):
    dense = _random_dense(64, 16, seed=6)
    s = _store_from_dense(tmp_path, dense, [32, 32])
    corrupt_block(s.directory, 0, "bitflip")
    st = TxStore.open(s.directory, verify=False)
    st.read_block(0)                      # geometry intact ⇒ readable
    with pytest.raises(ChecksumMismatchError):
        TxStore.open(s.directory).read_block(0)


# ---------------------------------------------------------------------------
# fsck — classification, repair, quarantine; the CLI exit contract
# ---------------------------------------------------------------------------


def test_fsck_classifies_every_damage_kind(tmp_path):
    dense = _random_dense(160, 16, seed=7)
    s = _store_from_dense(tmp_path, dense, [32, 32, 32, 32, 32])
    corrupt_block(s.directory, 0, "bitflip")
    corrupt_block(s.directory, 1, "truncate")
    corrupt_block(s.directory, 2, "delete")
    corrupt_block(s.directory, 3, "stale")
    orphan_block(s.directory, n_rows=4)
    rep = fsck(s.directory)               # read-only scan
    kinds = sorted(d.kind for d in rep.damages)
    assert kinds == ["bit-flip", "missing", "orphan", "stale-manifest",
                     "truncated"]
    assert not rep.clean and all(d.action == "none" for d in rep.damages)


def test_fsck_quarantine_salvages_survivors(tmp_path):
    dense = _random_dense(128, 16, seed=8)
    s = _store_from_dense(tmp_path, dense, [32, 32, 32, 32])
    corrupt_block(s.directory, 1, "bitflip")
    corrupt_block(s.directory, 3, "delete")
    rep = fsck(s.directory, quarantine=True)
    assert rep.clean and rep.n_blocks == 2 and rep.n_tx == 64
    q = os.path.join(s.directory, "quarantine")
    assert os.listdir(q) == ["block_000001.npy"]      # deleted one is gone
    st = TxStore.open(s.directory)
    got = np.concatenate([st.read_block(i) for i in range(st.n_blocks)])
    from repro.store import pack_bool_np
    want = np.concatenate([pack_bool_np(dense[0:32]),
                           pack_bool_np(dense[64:96])])
    assert np.array_equal(got, want)      # exactly the undamaged payloads
    assert fsck(s.directory).damages == []


def test_fsck_cli_exit_codes(tmp_path, capsys, monkeypatch):
    from repro.launch import fsck as cli

    dense = _random_dense(64, 16, seed=9)
    s = _store_from_dense(tmp_path, dense, [32, 32])

    def run(*argv):
        monkeypatch.setattr("sys.argv", ["fsck", *argv])
        with pytest.raises(SystemExit) as ei:
            cli.main()
        return ei.value.code or 0, capsys.readouterr().out

    code, out = run(s.directory)
    assert code == 0 and "clean" in out
    corrupt_block(s.directory, 0, "bitflip")
    code, _ = run(s.directory)
    assert code == 1                      # damage found, nothing done
    code, _ = run(s.directory, "--quarantine")
    assert code == 0                      # damage handled
    code, _ = run(s.directory)
    assert code == 0                      # now clean
    code, _ = run(str(tmp_path / "nowhere"))
    assert code == 2                      # not a store


# ---------------------------------------------------------------------------
# StoreWriter crash window — resume adopts or deletes residue, deterministic
# ---------------------------------------------------------------------------


def test_writer_resume_adopts_crash_residue(tmp_path):
    dense = _random_dense(64, 12, seed=10)
    s = _store_from_dense(tmp_path, dense, [32, 32])
    # crash between np.save and manifest flush: two valid orphans
    orphan_block(s.directory, n_rows=8)
    orphan_block(s.directory, n_rows=4)
    w = StoreWriter(s.directory, n_items=12, block_tx=32, resume=True)
    st = w.close()
    assert st.n_blocks == 4 and st.n_tx == 64 + 12
    assert st.manifest.item_counts[0] >= 12   # adopted rows counted exactly
    # adoption is deterministic: a second resume finds nothing left to do
    with open(os.path.join(s.directory, "manifest.json")) as f:
        m1 = json.load(f)
    StoreWriter(s.directory, n_items=12, block_tx=32, resume=True).close()
    with open(os.path.join(s.directory, "manifest.json")) as f:
        assert json.load(f) == m1


def test_writer_resume_deletes_torn_and_gapped_residue(tmp_path):
    dense = _random_dense(32, 12, seed=11)
    s = _store_from_dense(tmp_path, dense, [32])
    torn = orphan_block(s.directory, n_rows=8, torn=True)
    gapped = orphan_block(s.directory, n_rows=8, index=7)
    w = StoreWriter(s.directory, n_items=12, block_tx=32, resume=True)
    st = w.close()
    assert st.n_blocks == 1 and st.n_tx == 32     # neither was adoptable
    assert not os.path.exists(torn) and not os.path.exists(gapped)


def test_writer_resume_names_blocks_past_quarantine_gap(tmp_path):
    dense = _random_dense(96, 12, seed=12)
    s = _store_from_dense(tmp_path, dense, [32, 32, 32])
    corrupt_block(s.directory, 1, "bitflip")
    fsck(s.directory, quarantine=True)            # blocks/ now has 0 and 2
    w = StoreWriter(s.directory, n_items=12, block_tx=32, resume=True)
    w.append_dense(_random_dense(32, 12, seed=13))
    st = w.close()
    files = sorted(b.file for b in st.manifest.blocks)
    assert files == [os.path.join("blocks", f"block_{i:06d}.npy")
                     for i in (0, 2, 3)]          # never reuses a live name
    fsck(s.directory)                             # and the result is clean


# ---------------------------------------------------------------------------
# Checkpointed cluster rounds — kill, resume, bit-exact
# ---------------------------------------------------------------------------


def _cluster_setup():
    from repro import cluster

    dense = _random_dense(128, 16, seed=14, density=0.35)
    shards = fimi.shard_db(np.asarray(dense), 2)
    params = cluster.ClusterParams(
        planner=cluster.PlannerParams(min_support_rel=0.15, n_db_sample=128,
                                      n_fi_sample=128),
        eclat=eclat.EclatConfig(max_out=1 << 13, max_stack=2048,
                                frontier_size=8),
        chunk=1,                          # force several rounds
    )
    return cluster, shards, params, jax.random.PRNGKey(1)


def test_kill_and_resume_is_bit_exact(tmp_path):
    cluster, shards, params, key = _cluster_setup()
    ref = cluster.execute(shards, 16, params, key)
    assert ref.report.n_rounds >= 3       # the kill must land mid-run
    ck = str(tmp_path / "ck")
    with pytest.raises(SimulatedCrash):
        cluster.execute(shards, 16, params, key, checkpoint_dir=ck,
                        round_hook=kill_after_round(1))
    res = cluster.execute(shards, 16, params, key, checkpoint_dir=ck,
                          resume=True)
    assert np.array_equal(res.table.masks, ref.table.masks)
    assert np.array_equal(res.table.supports, ref.table.supports)
    assert res.report.n_rounds == ref.report.n_rounds
    assert np.array_equal(res.report.observed_loads, ref.report.observed_loads)
    assert res.report.donations == ref.report.donations


def test_resume_refuses_foreign_or_corrupt_checkpoint(tmp_path):
    import dataclasses

    cluster, shards, params, key = _cluster_setup()
    ck = str(tmp_path / "ck")
    with pytest.raises(SimulatedCrash):
        cluster.execute(shards, 16, params, key, checkpoint_dir=ck,
                        round_hook=kill_after_round(0))
    # different support threshold ⇒ different plan ⇒ refuse
    p2 = dataclasses.replace(params, planner=dataclasses.replace(
        params.planner, min_support_rel=0.3))
    with pytest.raises(cluster.CheckpointError, match="different run"):
        cluster.execute(shards, 16, p2, key, checkpoint_dir=ck, resume=True)
    # flip a payload bit ⇒ CRC mismatch ⇒ refuse
    payload = [f for f in os.listdir(ck) if f.endswith(".npz")][0]
    with open(os.path.join(ck, payload), "r+b") as f:
        raw = bytearray(f.read())
        raw[len(raw) // 2] ^= 0x01
        f.seek(0)
        f.write(raw)
    with pytest.raises(cluster.CheckpointError, match="corrupt"):
        cluster.execute(shards, 16, params, key, checkpoint_dir=ck,
                        resume=True)


def test_resume_without_checkpoint_runs_fresh(tmp_path):
    cluster, shards, params, key = _cluster_setup()
    ref = cluster.execute(shards, 16, params, key)
    res = cluster.execute(shards, 16, params, key,
                          checkpoint_dir=str(tmp_path / "empty"), resume=True)
    assert res.table.to_dict() == ref.table.to_dict()
