"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import bitmap as bm
from repro.kernels import bitmap_support as bs
from repro.kernels import pair_support as ps
from repro.kernels import ops, ref


def _random_db(n_tx, n_items, seed, density=0.3):
    rng = np.random.default_rng(seed)
    dense = rng.random((n_tx, n_items)) < density
    return bm.BitmapDB.from_dense(jnp.asarray(dense))


SHAPES = [
    (33, 7),      # sub-tile everything
    (128, 16),    # word-aligned tx
    (257, 64),    # prime tx count
    (1024, 130),  # multi-tile items
    (4096, 96),   # multi-tile words
]


@pytest.mark.parametrize("n_tx,n_items", SHAPES)
def test_extension_supports_kernel_sweep(n_tx, n_items):
    db = _random_db(n_tx, n_items, seed=n_tx + n_items)
    tid = db.all_tids()
    want = np.asarray(ref.extension_supports_ref(db.item_bits, tid))
    got = np.asarray(
        bs.extension_supports_pallas(db.item_bits, tid, interpret=True)
    )
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("block_i,block_w", [(8, 128), (64, 256), (256, 512)])
def test_extension_supports_block_shapes(block_i, block_w):
    db = _random_db(777, 53, seed=9)
    tid = db.all_tids()
    want = np.asarray(ref.extension_supports_ref(db.item_bits, tid))
    got = np.asarray(
        bs.extension_supports_pallas(
            db.item_bits, tid, block_i=block_i, block_w=block_w, interpret=True
        )
    )
    np.testing.assert_array_equal(got, want)


def test_extension_supports_with_prefix_tid():
    """Kernel must respect an arbitrary (non-trivial) prefix tidlist."""
    db = _random_db(512, 24, seed=4)
    prefix = np.zeros(24, bool)
    prefix[[3, 7]] = True
    tid = bm.tidlist_of_itemset(db, jnp.asarray(prefix))
    want = np.asarray(ref.extension_supports_ref(db.item_bits, tid))
    got = np.asarray(bs.extension_supports_pallas(db.item_bits, tid, interpret=True))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("n_tx,n_items", [(64, 9), (300, 40), (1024, 70)])
def test_pair_supports_vpu_sweep(n_tx, n_items):
    db = _random_db(n_tx, n_items, seed=n_tx)
    tid = db.all_tids()
    want = np.asarray(ref.pair_supports_ref(db.item_bits, tid))
    got = np.asarray(
        ps.pair_supports_pallas(
            db.item_bits, tid, block_i=16, block_j=16, block_w=128, interpret=True
        )
    )
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("n_tx,n_items", [(64, 9), (300, 40), (1024, 70)])
def test_pair_supports_mxu_sweep(n_tx, n_items):
    """The beyond-paper unpack+MXU-dot kernel is exact (counts < 2^24)."""
    db = _random_db(n_tx, n_items, seed=n_tx + 1)
    tid = db.all_tids()
    want = np.asarray(ref.pair_supports_ref(db.item_bits, tid))
    got = np.asarray(
        ps.pair_supports_mxu_pallas(
            db.item_bits, tid, block_i=16, block_j=16, block_w=8, interpret=True
        )
    )
    np.testing.assert_array_equal(got, want)
    # jnp MXU reference agrees too
    got_ref = np.asarray(ref.pair_supports_mxu_ref(db.item_bits, tid))
    np.testing.assert_array_equal(got_ref, want)


def test_ops_dispatch_cpu():
    db = _random_db(256, 20, seed=2)
    tid = db.all_tids()
    a = np.asarray(ops.extension_supports(db.item_bits, tid))
    b = np.asarray(ops.extension_supports(db.item_bits, tid, force="interpret"))
    np.testing.assert_array_equal(a, b)
    c = np.asarray(ops.pair_supports(db.item_bits, tid, use_mxu=True))
    d = np.asarray(ops.pair_supports(db.item_bits, tid, use_mxu=False))
    np.testing.assert_array_equal(c, d)


def test_kernel_plugs_into_eclat(small_db):
    """End-to-end: Eclat driven by the Pallas kernel (interpret) == oracle."""
    dense, db, minsup, oracle = small_db
    from repro.core import eclat

    def support_fn(item_bits, tid):
        return bs.extension_supports_pallas(item_bits, tid, interpret=True)

    res = eclat.mine_all(
        db, minsup,
        config=eclat.EclatConfig(max_out=8192, max_stack=2048),
        support_fn=support_fn,
    )
    assert int(res.n_total) == len(oracle)
