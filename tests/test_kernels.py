"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import bitmap as bm
from repro.kernels import bitmap_support as bs
from repro.kernels import multi_support as ms
from repro.kernels import pair_support as ps
from repro.kernels import ops, ref


def _random_db(n_tx, n_items, seed, density=0.3):
    rng = np.random.default_rng(seed)
    dense = rng.random((n_tx, n_items)) < density
    return bm.BitmapDB.from_dense(jnp.asarray(dense))


SHAPES = [
    (33, 7),      # sub-tile everything
    (128, 16),    # word-aligned tx
    (257, 64),    # prime tx count
    (1024, 130),  # multi-tile items
    (4096, 96),   # multi-tile words
]


@pytest.mark.parametrize("n_tx,n_items", SHAPES)
def test_extension_supports_kernel_sweep(n_tx, n_items):
    db = _random_db(n_tx, n_items, seed=n_tx + n_items)
    tid = db.all_tids()
    want = np.asarray(ref.extension_supports_ref(db.item_bits, tid))
    got = np.asarray(
        bs.extension_supports_pallas(db.item_bits, tid, interpret=True)
    )
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("block_i,block_w", [(8, 128), (64, 256), (256, 512)])
def test_extension_supports_block_shapes(block_i, block_w):
    db = _random_db(777, 53, seed=9)
    tid = db.all_tids()
    want = np.asarray(ref.extension_supports_ref(db.item_bits, tid))
    got = np.asarray(
        bs.extension_supports_pallas(
            db.item_bits, tid, block_i=block_i, block_w=block_w, interpret=True
        )
    )
    np.testing.assert_array_equal(got, want)


def test_extension_supports_with_prefix_tid():
    """Kernel must respect an arbitrary (non-trivial) prefix tidlist."""
    db = _random_db(512, 24, seed=4)
    prefix = np.zeros(24, bool)
    prefix[[3, 7]] = True
    tid = bm.tidlist_of_itemset(db, jnp.asarray(prefix))
    want = np.asarray(ref.extension_supports_ref(db.item_bits, tid))
    got = np.asarray(bs.extension_supports_pallas(db.item_bits, tid, interpret=True))
    np.testing.assert_array_equal(got, want)


# ragged (n_tx, n_items, K) sweeps: sub-tile, word-aligned, prime, multi-tile
MULTI_SHAPES = [
    (33, 7, 1),       # sub-tile everything, K=1 degenerate frontier
    (128, 16, 3),     # word-aligned tx, tiny ragged K
    (257, 64, 8),     # prime tx count
    (300, 40, 13),    # ragged everything
    (1024, 130, 5),   # multi-tile items
    (512, 24, 64),    # wide frontier
]


def _random_tids(db, k, seed):
    """K prefix tidlists: tidlists of random small itemsets (incl. ∅)."""
    rng = np.random.default_rng(seed)
    tids = []
    for j in range(k):
        mask = np.zeros(db.n_items, bool)
        n_members = int(rng.integers(0, 3))
        mask[rng.choice(db.n_items, size=n_members, replace=False)] = True
        tids.append(np.asarray(bm.tidlist_of_itemset(db, jnp.asarray(mask))))
    return jnp.asarray(np.stack(tids))


@pytest.mark.parametrize("n_tx,n_items,k", MULTI_SHAPES)
def test_multi_extension_supports_vpu_sweep(n_tx, n_items, k):
    db = _random_db(n_tx, n_items, seed=n_tx + n_items + k)
    tids = _random_tids(db, k, seed=k)
    want = np.asarray(ref.multi_extension_supports_ref(db.item_bits, tids))
    got = np.asarray(
        ms.multi_extension_supports_pallas(db.item_bits, tids, interpret=True)
    )
    np.testing.assert_array_equal(got, want)
    # row k of the fused sweep == the single-prefix kernel on tid_k
    for j in range(min(k, 3)):
        row = np.asarray(
            bs.extension_supports_pallas(db.item_bits, tids[j], interpret=True)
        )
        np.testing.assert_array_equal(want[j], row)


@pytest.mark.parametrize("n_tx,n_items,k", MULTI_SHAPES)
def test_multi_extension_supports_mxu_sweep(n_tx, n_items, k):
    """The unpack+MXU-dot multi-prefix form is exact (counts < 2^24)."""
    db = _random_db(n_tx, n_items, seed=n_tx + k)
    tids = _random_tids(db, k, seed=k + 1)
    want = np.asarray(ref.multi_extension_supports_ref(db.item_bits, tids))
    got = np.asarray(
        ms.multi_extension_supports_mxu_pallas(
            db.item_bits, tids, block_k=8, block_i=16, block_w=8, interpret=True
        )
    )
    np.testing.assert_array_equal(got, want)
    # jnp MXU reference agrees too
    got_ref = np.asarray(
        ref.multi_extension_supports_mxu_ref(db.item_bits, tids)
    )
    np.testing.assert_array_equal(got_ref, want)


@pytest.mark.parametrize("block_k,block_i,block_w", [(8, 8, 128), (8, 64, 256)])
def test_multi_extension_supports_block_shapes(block_k, block_i, block_w):
    db = _random_db(777, 53, seed=11)
    tids = _random_tids(db, 10, seed=12)
    want = np.asarray(ref.multi_extension_supports_ref(db.item_bits, tids))
    got = np.asarray(
        ms.multi_extension_supports_pallas(
            db.item_bits, tids,
            block_k=block_k, block_i=block_i, block_w=block_w, interpret=True,
        )
    )
    np.testing.assert_array_equal(got, want)


def test_multi_ops_dispatch_cpu():
    db = _random_db(256, 20, seed=5)
    tids = _random_tids(db, 6, seed=6)
    a = np.asarray(ops.multi_extension_supports(db.item_bits, tids))
    b = np.asarray(
        ops.multi_extension_supports(db.item_bits, tids, force="interpret")
    )
    np.testing.assert_array_equal(a, b)
    c = np.asarray(ops.multi_extension_supports(db.item_bits, tids, use_mxu=True))
    np.testing.assert_array_equal(a, c)
    d = np.asarray(
        ops.multi_extension_supports(
            db.item_bits, tids, use_mxu=True, force="interpret"
        )
    )
    np.testing.assert_array_equal(a, d)


@pytest.mark.parametrize("n_tx,n_items", [(64, 9), (300, 40), (1024, 70)])
def test_pair_supports_vpu_sweep(n_tx, n_items):
    db = _random_db(n_tx, n_items, seed=n_tx)
    tid = db.all_tids()
    want = np.asarray(ref.pair_supports_ref(db.item_bits, tid))
    got = np.asarray(
        ps.pair_supports_pallas(
            db.item_bits, tid, block_i=16, block_j=16, block_w=128, interpret=True
        )
    )
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("n_tx,n_items", [(64, 9), (300, 40), (1024, 70)])
def test_pair_supports_mxu_sweep(n_tx, n_items):
    """The beyond-paper unpack+MXU-dot kernel is exact (counts < 2^24)."""
    db = _random_db(n_tx, n_items, seed=n_tx + 1)
    tid = db.all_tids()
    want = np.asarray(ref.pair_supports_ref(db.item_bits, tid))
    got = np.asarray(
        ps.pair_supports_mxu_pallas(
            db.item_bits, tid, block_i=16, block_j=16, block_w=8, interpret=True
        )
    )
    np.testing.assert_array_equal(got, want)
    # jnp MXU reference agrees too
    got_ref = np.asarray(ref.pair_supports_mxu_ref(db.item_bits, tid))
    np.testing.assert_array_equal(got_ref, want)


def test_ops_dispatch_cpu():
    db = _random_db(256, 20, seed=2)
    tid = db.all_tids()
    a = np.asarray(ops.extension_supports(db.item_bits, tid))
    b = np.asarray(ops.extension_supports(db.item_bits, tid, force="interpret"))
    np.testing.assert_array_equal(a, b)
    c = np.asarray(ops.pair_supports(db.item_bits, tid, use_mxu=True))
    d = np.asarray(ops.pair_supports(db.item_bits, tid, use_mxu=False))
    np.testing.assert_array_equal(c, d)


def test_kernel_plugs_into_eclat(small_db):
    """End-to-end: Eclat driven by the Pallas kernel (interpret) == oracle."""
    dense, db, minsup, oracle = small_db
    from repro.core import eclat

    def support_fn(item_bits, tid):
        return bs.extension_supports_pallas(item_bits, tid, interpret=True)

    res = eclat.mine_all(
        db, minsup,
        config=eclat.EclatConfig(max_out=8192, max_stack=2048),
        support_fn=support_fn,
    )
    assert int(res.n_total) == len(oracle)


def test_multi_kernel_plugs_into_frontier_eclat(small_db):
    """Frontier-batched Eclat driven by the fused multi-prefix Pallas kernel
    (interpret mode) == oracle."""
    dense, db, minsup, oracle = small_db
    from repro.core import eclat

    def multi_support_fn(item_bits, tids):
        return ms.multi_extension_supports_pallas(item_bits, tids, interpret=True)

    res = eclat.mine_all(
        db, minsup,
        config=eclat.EclatConfig(max_out=8192, max_stack=2048, frontier_size=8),
        multi_support_fn=multi_support_fn,
    )
    assert int(res.stack_overflow) == 0
    assert int(res.n_total) == len(oracle)
