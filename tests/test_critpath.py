"""Span-DAG reconstruction and critical-path analysis (repro.obs.critpath):
per-track nesting, cross-track containment, instant attachment, exclusive
self-time as an interval union, straggler selection among parallel lanes,
untraced-gap accounting, and the analyze() digest over the committed golden
fixture records."""
import json
from pathlib import Path

from repro.obs import critpath

REPO = Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tests" / "data"


def _x(name, ts, dur, tid=1, **args):
    return {"ph": "X", "name": name, "pid": 0, "tid": tid, "ts": ts,
            "dur": dur, "args": args}


def _meta(tid, name):
    return {"ph": "M", "name": "thread_name", "pid": 0, "tid": tid,
            "args": {"name": name}}


def _trace(*events):
    return {"traceEvents": list(events), "displayTimeUnit": "ms"}


# ---------------------------------------------------------------------------
# build: nesting / cross-track attachment / instants
# ---------------------------------------------------------------------------


def test_build_empty_or_spanless_returns_none():
    assert critpath.build(None) is None
    assert critpath.build({}) is None
    assert critpath.build(_trace(_meta(1, "Main"))) is None


def test_same_track_nesting_is_innermost_container():
    dag = critpath.build(_trace(
        _x("outer", 0, 1000),
        _x("mid", 100, 500),
        _x("inner", 200, 100),
        _x("sibling", 700, 200),
    ))
    by_name = {s.name: s for s in dag.nodes}
    assert by_name["mid"].parent is by_name["outer"]
    assert by_name["inner"].parent is by_name["mid"]
    assert by_name["sibling"].parent is by_name["outer"]
    # the virtual root owns the single real root
    assert by_name["outer"].parent is dag.root
    assert dag.root.name == critpath.UNTRACED
    assert dag.wall_us == 1000


def test_cross_track_lane_attaches_to_containing_span():
    dag = critpath.build(_trace(
        _meta(1, "Main"), _meta(1000001, "shard0"),
        _x("cluster/mine", 0, 1000, tid=1),
        _x("cluster/mine", 0, 800, tid=1000001),
    ))
    main = next(s for s in dag.nodes if s.tid == 1)
    lane = next(s for s in dag.nodes if s.tid == 1000001)
    assert lane.parent is main
    assert lane.track == "shard0" and main.track == "Main"


def test_cross_track_attach_tolerates_eps_overhang():
    # the lane starts slightly before its host (clock skew < _EPS_US)
    dag = critpath.build(_trace(
        _x("host", 1000, 5000, tid=1),
        _x("lane", 1000 - critpath._EPS_US / 2, 5000, tid=2),
    ))
    lane = next(s for s in dag.nodes if s.name == "lane")
    assert lane.parent.name == "host"


def test_disjoint_cross_track_span_stays_a_root():
    dag = critpath.build(_trace(
        _x("a", 0, 1000, tid=1),
        _x("b", 50_000, 1000, tid=2),
    ))
    roots = [s for s in dag.nodes if s.parent is dag.root]
    assert sorted(s.name for s in roots) == ["a", "b"]
    assert dag.wall_us == 51_000


def test_instants_annotate_innermost_enclosing_span():
    dag = critpath.build(_trace(
        _x("outer", 0, 1000),
        _x("inner", 200, 400),
        {"ph": "i", "name": "cluster/donate", "pid": 0, "tid": 1, "ts": 300,
         "s": "t", "args": {"src": 1, "dst": 0}},
    ))
    by_name = {s.name: s for s in dag.nodes}
    assert [i["name"] for i in by_name["inner"].instants] == \
        ["cluster/donate"]
    assert by_name["outer"].instants == []


# ---------------------------------------------------------------------------
# exclusive self-time: union of child intervals, never a naive sum
# ---------------------------------------------------------------------------


def test_exclusive_subtracts_union_of_overlapping_children():
    dag = critpath.build(_trace(
        _x("parent", 0, 10_000, tid=1),
        # two parallel lanes overlapping on [3000, 5000): union 9000, not
        # the naive sum 11000 (which would clamp the parent to zero)
        _x("lane", 0, 5000, tid=2),
        _x("lane", 3000, 6000, tid=3),
    ))
    parent = next(s for s in dag.nodes if s.name == "parent")
    assert [c.name for c in parent.children] == ["lane", "lane"]
    assert parent.exclusive_us() == 10_000 - 9000
    totals = critpath.exclusive_totals(dag)
    assert totals["parent"]["self_ms"] == 1.0
    assert totals["lane"]["count"] == 2
    assert totals["lane"]["total_ms"] == 11.0


def test_exclusive_clips_children_to_parent_interval():
    # a child overhanging the parent's end (eps attach slack) only erases
    # the part of itself inside the parent
    dag = critpath.build(_trace(
        _x("parent", 0, 5000, tid=1),
        _x("lane", 2500, 3000, tid=2),       # ends 500 us past the parent
    ))
    parent = next(s for s in dag.nodes if s.name == "parent")
    assert [c.name for c in parent.children] == ["lane"]
    assert parent.exclusive_us() == 5000 - 2500     # not 5000 - 3000


def test_union_len():
    assert critpath._union_len([]) == 0.0
    assert critpath._union_len([(0, 10), (20, 30)]) == 20.0
    assert critpath._union_len([(0, 10), (5, 15), (15, 20)]) == 20.0
    assert critpath._union_len([(0, 10), (2, 3)]) == 10.0


# ---------------------------------------------------------------------------
# critical path: straggler lanes, untraced gaps, full accounting
# ---------------------------------------------------------------------------


def test_parallel_lanes_resolve_to_the_straggler():
    dag = critpath.build(_trace(
        _meta(1000001, "shard0"), _meta(1000002, "shard1"),
        _x("round", 0, 1000, tid=1),
        _x("mine", 0, 1000, tid=1000001),     # straggler
        _x("mine", 0, 400, tid=1000002),      # shadowed: slack, not critical
    ))
    segs = critpath.critical_path(dag)
    on_path = [(s.name, s.track) for s in segs]
    assert ("mine", "shard0") in on_path
    assert ("mine", "shard1") not in on_path
    # the straggler covers the round: the round has no on-path self time
    round_seg = next(s for s in segs if s.name == "round")
    assert round_seg.self_us == 0.0


def test_untraced_gaps_become_root_self_time():
    dag = critpath.build(_trace(
        _x("a", 0, 1000),
        _x("b", 3000, 1000),
    ))
    segs = critpath.critical_path(dag)
    root = segs[0]
    assert root.name == critpath.UNTRACED
    assert root.self_us == 2000.0           # the [1000, 3000) gap
    # self times over the path account the full wall exactly
    assert sum(s.self_us for s in segs) == dag.wall_us


def test_sequential_chain_fully_selected():
    dag = critpath.build(_trace(
        _x("outer", 0, 1000),
        _x("s1", 0, 300),
        _x("s2", 300, 700),
    ))
    segs = critpath.critical_path(dag)
    assert [s.name for s in segs] == [critpath.UNTRACED, "outer", "s1", "s2"]
    assert segs[1].self_us == 0.0


def test_path_table_aggregates_and_ranks():
    dag = critpath.build(_trace(
        _x("big", 0, 1000),
        _x("small", 2000, 100),
    ))
    rows = critpath.path_table(critpath.critical_path(dag))
    assert rows[0]["name"] in (critpath.UNTRACED, "big")
    names = [r["name"] for r in rows]
    assert "big" in names and "small" in names
    assert abs(sum(r["share"] for r in rows) - 1.0) < 1e-9
    # top_n truncates
    assert len(critpath.path_table(critpath.critical_path(dag), top_n=1)) == 1


# ---------------------------------------------------------------------------
# analyze() over the committed golden fixtures
# ---------------------------------------------------------------------------


def _fixture_trace(name):
    return json.loads((FIXTURES / name / "trace.json").read_text())


def test_analyze_healthy_fixture():
    cp = critpath.analyze(_fixture_trace("run_healthy"))
    assert cp is not None
    assert abs(cp["wall_ms"] - 108.2) < 1e-6
    # the straggler shard lane IS the mine phase's critical time
    top = cp["table"][0]
    assert top["name"] == "cluster/mine"
    assert abs(top["self_ms"] - 100.0) < 1e-6
    assert "shard0" in top["tracks"]
    # the shadowed shard1 lane never appears on the path
    assert not any(seg["track"] == "shard1" for seg in cp["path"])
    # exclusive totals: both lanes fully cover the main-track mine span
    assert cp["exclusive"]["cluster/mine"]["count"] == 3
    # on-path self times account the full wall
    assert abs(sum(s["self_ms"] for s in cp["path"]) - cp["wall_ms"]) < 1e-6


def test_analyze_skewed_fixture_counts_both_rounds():
    cp = critpath.analyze(_fixture_trace("run_skewed_cluster"))
    top = cp["table"][0]
    assert top["name"] == "cluster/mine"
    assert abs(top["self_ms"] - 200.0) < 1e-6     # straggler lane, 2 rounds
    ex = cp["exclusive"]["cluster/exchange"]
    assert ex["count"] == 2 and abs(ex["total_ms"] - 2.0) < 1e-6


def test_analyze_no_trace_returns_none():
    assert critpath.analyze(None) is None
    assert critpath.analyze({"traceEvents": []}) is None
