"""MiningService front end: parity with the direct engine on all three
query kinds, deterministic micro-batch coalescing, typed admission-control
sheds, generation-consistent hot-swap across replicas, cache hits on the
service path, per-request trace spans, and drain-on-stop semantics."""
import json
import time

import numpy as np
import pytest

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.slo import SLOPolicy, SLOTracker
from repro.serve import (
    Failed,
    MiningService,
    QueryCache,
    QueryEngine,
    Shed,
)
from repro.serve.index import build_indexes


@pytest.fixture(autouse=True)
def _fresh_obs():
    obs_metrics.reset()
    obs_trace.TRACER.disable()
    obs_trace.TRACER.clear()
    yield
    obs_metrics.reset()
    obs_trace.TRACER.disable()
    obs_trace.TRACER.clear()


@pytest.fixture(scope="module")
def indexed(request):
    dense, db, minsup, oracle = request.getfixturevalue("small_db")
    fi_idx, rule_idx = build_indexes(oracle, db.n_items, db.n_tx,
                                     min_confidence=0.6)
    return dense, db, oracle, fi_idx, rule_idx


def _engine(indexed, **kw):
    *_, fi_idx, rule_idx = indexed
    kw.setdefault("batch", 32)
    kw.setdefault("top_k", 5)
    return QueryEngine(fi_idx, rule_idx, **kw)


def _drain(svc, tickets, timeout=60.0):
    return [t.result(timeout) for t in tickets]


# ---------------------------------------------------------------------------
# Parity: service answers == direct engine answers, all three kinds
# ---------------------------------------------------------------------------


def test_service_matches_direct_engine(indexed):
    dense, db, oracle, *_ = indexed
    engine = _engine(indexed)
    sets = sorted(oracle, key=lambda s: (len(s), tuple(sorted(s))))[:12]
    baskets = [frozenset(np.nonzero(dense[t])[0].tolist()) for t in range(8)]
    set_masks = np.asarray(engine.pack(sets))
    basket_masks = np.asarray(engine.pack(baskets))

    want_supp = engine.support(set_masks)
    want_rows, want_conf = engine.rules_for(basket_masks)
    want_srows, want_ssupp = engine.supersets(set_masks)

    with MiningService([engine], deadline_ms=2.0) as svc:
        t_supp = [svc.submit("support", m) for m in set_masks]
        t_rule = [svc.submit("rules", m) for m in basket_masks]
        t_sup = [svc.submit("superset", m) for m in set_masks]
        got_supp = _drain(svc, t_supp)
        got_rule = _drain(svc, t_rule)
        got_sup = _drain(svc, t_sup)

    np.testing.assert_array_equal(got_supp, want_supp)
    for i, (rows, conf) in enumerate(got_rule):
        np.testing.assert_array_equal(rows, want_rows[i])
        np.testing.assert_array_equal(conf, want_conf[i])  # NaN == NaN here
    for i, (rows, supp) in enumerate(got_sup):
        np.testing.assert_array_equal(rows, want_srows[i])
        np.testing.assert_array_equal(supp, want_ssupp[i])


def test_service_rejects_unknown_kind(indexed):
    engine = _engine(indexed)
    svc = MiningService([engine], auto_start=False)
    with pytest.raises(AssertionError):
        svc.submit("nope", np.zeros(engine.index.n_words, np.uint32))


# ---------------------------------------------------------------------------
# Micro-batching: a staged queue coalesces into one flush
# ---------------------------------------------------------------------------


def test_microbatch_coalesces_staged_queue(indexed):
    dense, db, oracle, *_ = indexed
    engine = _engine(indexed, batch=32)
    sets = list(oracle)[:32]
    masks = np.asarray(engine.pack(sets))
    svc = MiningService([engine], deadline_ms=50.0, auto_start=False)
    tickets = [svc.submit("support", m) for m in masks]
    assert svc.stats()["queue_depth"] == 32
    svc.start()
    got = _drain(svc, tickets)
    svc.stop()
    np.testing.assert_array_equal(got, [oracle[s] for s in sets])
    # 32 queued requests at width 32: exactly one flush, one full batch
    st = svc.stats()
    assert st["flushes"] == 1
    snap = obs_metrics.snapshot()
    assert snap["histograms"]["service/batch_fill"]["max"] == 32
    assert snap["gauges"]["service/queue_depth"] == 32  # high-water


def test_deadline_cuts_partial_batches(indexed):
    engine = _engine(indexed, batch=32)
    masks = np.asarray(engine.pack(list(indexed[2])[:3]))
    with MiningService([engine], deadline_ms=2.0) as svc:
        t0 = time.monotonic()
        got = _drain(svc, [svc.submit("support", m) for m in masks])
        dt = time.monotonic() - t0
    assert all(isinstance(v, np.integer) for v in got)
    assert dt < 30.0        # the deadline, not a full batch, cut the flush


# ---------------------------------------------------------------------------
# Admission control: typed sheds, never silent
# ---------------------------------------------------------------------------


def test_queue_full_sheds_typed(indexed):
    engine = _engine(indexed)
    oracle = indexed[2]
    masks = np.asarray(engine.pack(list(oracle)[:3]))
    slo = SLOTracker(SLOPolicy())
    svc = MiningService([engine], max_queue=2, auto_start=False, slo=slo)
    t1 = svc.submit("support", masks[0])
    t2 = svc.submit("support", masks[1])
    t3 = svc.submit("support", masks[2])         # over max_queue: shed NOW
    assert t3.done() and not t1.done() and not t2.done()
    out = t3.result(0)
    assert isinstance(out, Shed)
    assert out.reason == "queue_full" and out.queue_depth == 2
    assert obs_metrics.snapshot()["counters"]["service/shed"] == 1
    assert slo.evaluate().shed == 1
    svc.start()
    assert not isinstance(t1.result(60), Shed)
    svc.stop()


def test_stop_without_drain_sheds_queue(indexed):
    engine = _engine(indexed)
    masks = np.asarray(engine.pack(list(indexed[2])[:4]))
    svc = MiningService([engine], auto_start=False)
    tickets = [svc.submit("support", m) for m in masks]
    svc.stop(drain=False)
    for t in tickets:
        out = t.result(0)
        assert isinstance(out, Shed) and out.reason == "shutdown"
    with pytest.raises(RuntimeError):
        svc.submit("support", masks[0])


def test_stop_with_drain_resolves_everything(indexed):
    engine = _engine(indexed, batch=8)
    oracle = indexed[2]
    sets = list(oracle)[:20]
    masks = np.asarray(engine.pack(sets))
    svc = MiningService([engine], deadline_ms=100.0, auto_start=False)
    tickets = [svc.submit("support", m) for m in masks]
    svc.start()
    svc.stop(drain=True)
    got = [t.result(0) for t in tickets]         # all resolved already
    np.testing.assert_array_equal(got, [oracle[s] for s in sets])


# ---------------------------------------------------------------------------
# Replicas + generation-consistent hot swap
# ---------------------------------------------------------------------------


def test_round_robin_spreads_flushes(indexed):
    engine_a = _engine(indexed, batch=4)
    engine_b = _engine(indexed, batch=4)
    masks = np.asarray(engine_a.pack(list(indexed[2])[:16]))
    svc = MiningService([engine_a, engine_b], auto_start=False)
    tickets = [svc.submit("support", m) for m in masks]
    svc.start()
    _drain(svc, tickets)
    svc.stop()
    st = svc.stats()
    assert st["replicas"] == 2
    assert sum(st["per_replica_flushes"]) == st["flushes"] >= 4
    assert all(f > 0 for f in st["per_replica_flushes"])
    assert sum(st["per_replica_requests"]) == 16


def test_hot_swap_is_generation_consistent(indexed):
    dense, db, oracle, *_ = indexed
    cache = QueryCache(64)
    engines = [_engine(indexed), _engine(indexed)]
    # standby pair: only the singleton itemsets survive
    small = {f: s for f, s in oracle.items() if len(f) == 1}
    idx2, rules2 = build_indexes(small, db.n_items, db.n_tx,
                                 min_confidence=0.6)
    doomed = max(oracle, key=len)                # gone after the swap
    mask = np.asarray(engines[0].pack([doomed]))[0]
    with MiningService(engines, cache=cache, deadline_ms=2.0) as svc:
        assert svc.generation == 0
        assert svc.submit("support", mask).result(60) == oracle[doomed]
        assert len(cache) > 0
        gen = svc.swap_indexes(idx2, rules2)
        assert gen == svc.generation == 1
        assert {e.generation for e in svc.engines} == {1}
        assert len(cache) == 0                   # swap invalidated the cache
        assert cache.stats.invalidations == 1
        # the old answer is gone on EVERY replica (round-robin hits both)
        for _ in range(4):
            assert svc.submit("support", mask).result(60) == -1
    # a replica fleet must refuse to construct on diverged generations
    engines[0].swap_indexes(idx2, rules2)
    with pytest.raises(AssertionError):
        MiningService(engines, auto_start=False)


def test_cache_serves_repeats_and_updates_hit_rate_gauge(indexed):
    dense, db, oracle, *_ = indexed
    engine = _engine(indexed)
    cache = QueryCache(64)
    mask = np.asarray(engine.pack([next(iter(oracle))]))[0]
    with MiningService([engine], cache=cache, deadline_ms=2.0) as svc:
        first = svc.submit("support", mask).result(60)
        second = svc.submit("support", mask).result(60)
    assert first == second
    assert cache.stats.hits >= 1
    # the hit-rate gauge is maintained on the ACCESS path — visible in a
    # plain snapshot without anyone calling stats()
    g = obs_metrics.snapshot()["gauges"]
    assert g["serve/cache/hit_rate"] == pytest.approx(cache.stats.hit_rate)


# ---------------------------------------------------------------------------
# Per-request tracing: ids flow enqueue -> assemble -> sweep -> respond
# ---------------------------------------------------------------------------


def test_per_request_spans_share_request_ids(indexed):
    engine = _engine(indexed)
    masks = np.asarray(engine.pack(list(indexed[2])[:6]))
    tr = obs_trace.TRACER
    tr.enable()
    svc = MiningService([engine], deadline_ms=20.0, auto_start=False)
    tickets = [svc.submit("support", m) for m in masks]
    svc.start()
    _drain(svc, tickets)
    svc.stop()
    tr.disable()
    out = json.loads(json.dumps(tr.export()))    # byte round-trip
    assert isinstance(out["traceEvents"], list)  # Perfetto shape
    spans = [e for e in out["traceEvents"] if e.get("ph") == "X"]
    by_name = {}
    for e in spans:
        by_name.setdefault(e["name"], []).append(e)
    ids = {t.id for t in tickets}
    # one queue-wait span per request, carrying its id
    enq = by_name["service/enqueue"]
    assert {e["args"]["req"] for e in enq} == ids
    assert all(e["dur"] >= 0 for e in enq)
    # batch spans carry the member ids; the same ids appear at every stage
    for stage in ("service/flush", "service/assemble", "service/sweep",
                  "service/respond"):
        stage_ids = {i for e in by_name[stage] for i in e["args"]["reqs"]}
        assert stage_ids == ids, stage
    # the queue lane is a named virtual track
    tracks = {e["args"]["name"] for e in out["traceEvents"]
              if e.get("ph") == "M"}
    assert "service/replica0/queue" in tracks


def test_queue_depth_renders_as_counter_track(indexed):
    """Enqueue and dequeue both sample the 'queue depth' counter series, so
    Perfetto draws depth rising on submit and falling at batch cuts."""
    engine = _engine(indexed)
    masks = np.asarray(engine.pack(list(indexed[2])[:6]))
    tr = obs_trace.TRACER
    tr.enable()
    svc = MiningService([engine], deadline_ms=20.0, auto_start=False)
    tickets = [svc.submit("support", m) for m in masks]
    svc.start()
    _drain(svc, tickets)
    svc.stop()
    tr.disable()
    samples = [e for e in tr.export()["traceEvents"]
               if e.get("ph") == "C" and e.get("name") == "queue depth"]
    depths = [e["args"]["depth"] for e in samples]
    assert max(depths) >= 1          # staged while the dispatcher was parked
    assert min(depths) == 0          # ... and drained back down
    assert len(samples) >= len(masks)


def test_slo_tracker_fed_by_service(indexed):
    engine = _engine(indexed)
    masks = np.asarray(engine.pack(list(indexed[2])[:8]))
    slo = SLOTracker(SLOPolicy(p99_ms=60_000.0, min_requests=1))
    with MiningService([engine], slo=slo, deadline_ms=2.0) as svc:
        _drain(svc, [svc.submit("support", m) for m in masks])
    st = slo.evaluate()
    assert st.served == 8 and st.shed == 0 and st.errors == 0
    assert st.p99_ms is not None and st.p99_ms > 0
    assert not st.alert_active
    snap = obs_metrics.snapshot()
    assert snap["histograms"]["service/latency_ms"]["count"] == 8
