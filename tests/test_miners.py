"""Eclat / MFI / Apriori miners vs the brute-force oracle."""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st  # skips @given tests w/o hypothesis

import jax
import jax.numpy as jnp

from repro.core import apriori, bitmap as bm, eclat, mfi


def _to_dict(res, n_items):
    out = {}
    for k in range(int(res.n_out)):
        mask = np.asarray(bm.unpack_bool(res.items[k], n_items))
        out[frozenset(np.nonzero(mask)[0].tolist())] = int(res.supports[k])
    return out


def test_eclat_thesis_example(thesis_db):
    """|F| = 25 with min_support = 5 (thesis Example 2.1)."""
    res = eclat.mine_all(
        thesis_db, 5, config=eclat.EclatConfig(max_out=128, max_stack=64)
    )
    assert int(res.n_total) == 25 and int(res.stack_overflow) == 0
    got = _to_dict(res, 6)
    want = eclat.brute_force_fis(np.asarray(thesis_db.dense()), 5)
    assert got == want


def test_eclat_matches_bruteforce(small_db):
    dense, db, minsup, oracle = small_db
    res = eclat.mine_all(
        db, minsup, config=eclat.EclatConfig(max_out=8192, max_stack=2048)
    )
    assert int(res.stack_overflow) == 0
    assert _to_dict(res, db.n_items) == oracle


@given(st.integers(0, 10_000), st.floats(0.15, 0.5))
@settings(max_examples=8, deadline=None)
def test_eclat_property_random_dbs(seed, minsup_rel):
    """Property: Eclat == brute force on random small databases."""
    rng = np.random.default_rng(seed)
    dense = rng.random((64, 12)) < rng.uniform(0.2, 0.5)
    db = bm.BitmapDB.from_dense(jnp.asarray(dense))
    minsup = max(1, int(np.ceil(minsup_rel * 64)))
    res = eclat.mine_all(
        db, minsup, config=eclat.EclatConfig(max_out=8192, max_stack=2048)
    )
    assert int(res.stack_overflow) == 0
    assert _to_dict(res, 12) == eclat.brute_force_fis(dense, minsup)


def test_eclat_pbec_restriction(small_db):
    """Mining one PBEC yields exactly the oracle FIs in that class."""
    dense, db, minsup, oracle = small_db
    I = db.n_items
    prefix = np.zeros(I, bool)
    prefix[3] = True
    ext = np.zeros(I, bool)
    ext[4:] = True
    tid = bm.tidlist_of_itemset(db, jnp.asarray(prefix))
    res = eclat.mine(
        db.item_bits, jnp.asarray(prefix), jnp.asarray(ext), tid,
        jnp.asarray(minsup, jnp.int32), jax.random.PRNGKey(0),
        config=eclat.EclatConfig(max_out=4096, max_stack=1024), n_items=I,
    )
    got = _to_dict(res, I)
    want = {
        fs: s for fs, s in oracle.items()
        if 3 in fs and len(fs) > 1 and all(i >= 3 for i in fs)
    }
    assert got == want


def test_mfi_thesis_example(thesis_db):
    """M = {134, 234, 245, 3456} (thesis Example 2.1, 1-based)."""
    r = mfi.mine_all_candidates(thesis_db, 5, config=mfi.MFIConfig(max_out=256))
    n = int(r.n_out)
    valid = np.zeros(r.items.shape[0], bool)
    valid[:n] = True
    keep = np.asarray(mfi.filter_maximal(r.items, jnp.asarray(valid)))
    got = set()
    for k in range(n):
        if keep[k]:
            m = np.asarray(bm.unpack_bool(r.items[k], 6))
            got.add(tuple(sorted(int(i) + 1 for i in np.nonzero(m)[0])))
    assert got == {(1, 3, 4), (2, 3, 4), (2, 4, 5), (3, 4, 5, 6)}


def test_mfi_bound_thm_7_5(small_db):
    """Candidates form M ⊇ M̃ with all candidates frequent (Thm 7.5 setup)."""
    dense, db, minsup, oracle = small_db
    r = mfi.mine_all_candidates(
        db, minsup, config=mfi.MFIConfig(max_out=4096, max_stack=2048)
    )
    n = int(r.n_out)
    assert int(r.overflow) == 0
    mfis_true = {
        fs for fs in oracle
        if not any(fs < other for other in oracle)
    }
    cands = set()
    for k in range(n):
        m = np.asarray(bm.unpack_bool(r.items[k], db.n_items))
        fs = frozenset(np.nonzero(m)[0].tolist())
        assert fs in oracle, "candidate must be frequent"
        assert oracle[fs] == int(r.supports[k])
        cands.add(fs)
    assert mfis_true <= cands
    # longest-MFI bound of Thm 7.5 (with P=1 here: |M| = |M̃| after filtering)
    valid = np.zeros(r.items.shape[0], bool)
    valid[:n] = True
    keep = np.asarray(mfi.filter_maximal(r.items, jnp.asarray(valid)))
    kept = {
        frozenset(np.nonzero(np.asarray(bm.unpack_bool(r.items[k], db.n_items)))[0].tolist())
        for k in range(n) if keep[k]
    }
    assert kept == mfis_true


def test_apriori_matches_eclat(small_db):
    dense, db, minsup, oracle = small_db
    assert apriori.apriori(db, minsup) == oracle


def test_count_distribution_psum(small_db):
    """Alg. 2: per-shard counts + psum == global supports."""
    dense, db, minsup, oracle = small_db
    P = 4
    T = dense.shape[0] // P
    shards = dense[: P * T].reshape(P, T, -1)
    cands = sorted(oracle, key=lambda s: (len(s), tuple(sorted(s))))[:64]
    masks = np.zeros((len(cands), db.n_items), bool)
    for i, c in enumerate(cands):
        masks[i, sorted(c)] = True

    def shard_fn(sh):
        sdb = bm.BitmapDB.from_dense(sh)
        return apriori.count_distribution_supports(
            sdb.item_bits, jnp.asarray(masks), sdb.all_tids(), "p"
        )

    out = jax.vmap(shard_fn, axis_name="p")(jnp.asarray(shards))
    for i, c in enumerate(cands):
        assert int(out[0, i]) == oracle[c]
