"""Distributed mining executor: planner, executor parity, rebalancing.

The subsystem's headline invariant mirrors the paper's: however the sample
estimates the tree and however the rebalancer shuffles it, the merged result
is the EXACT frequent-itemset set of the whole database — asserted against
the brute-force oracle under vmap, under interpret-mode Pallas kernels on
ragged item counts, and (in a subprocess with its own device count) under
real 4-device shard_map.
"""
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import cluster
from repro.core import eclat, fimi, pbec


def _planner_params(**kw):
    base = dict(min_support_rel=0.08, n_db_sample=256, n_fi_sample=128,
                alpha=0.7)
    base.update(kw)
    return cluster.PlannerParams(**base)


@pytest.fixture(scope="module")
def small_plan(small_db):
    dense, db, minsup, oracle = small_db
    shards = fimi.shard_db(dense, 4)
    plan = cluster.plan(shards, 24, _planner_params(), jax.random.PRNGKey(3))
    return dense, oracle, shards, plan


# ---------------------------------------------------------------------------
# Planner
# ---------------------------------------------------------------------------


def test_planner_deterministic(small_db):
    """Same inputs + key ⇒ identical plan (multi-host agreement requirement)."""
    dense, db, minsup, oracle = small_db
    shards = fimi.shard_db(dense, 4)
    a = cluster.plan(shards, 24, _planner_params(), jax.random.PRNGKey(3))
    b = cluster.plan(shards, 24, _planner_params(), jax.random.PRNGKey(3))
    assert np.array_equal(a.assignment, b.assignment)
    assert np.array_equal(a.est_sizes, b.est_sizes)
    assert a.scheduler_used == b.scheduler_used
    assert [c.seq for c in a.classes] == [c.seq for c in b.classes]
    assert a.shard_queues() == b.shard_queues()


def test_planner_estimation_error_thm61(small_db):
    """Thm 6.1: item supports on D̃ are within ε of the true supports, and the
    class-size shares the scheduler balances on track the exact FI shares."""
    dense, db, minsup, oracle = small_db
    shards = fimi.shard_db(dense, 4)
    plan = cluster.plan(shards, 24, _planner_params(), jax.random.PRNGKey(3))

    true_rel = dense.mean(axis=0)
    err = np.abs(plan.sample_item_rel - true_rel).max()
    # the bound holds w.p. 1−δ; this seed is fixed, so assert it outright
    assert err <= plan.eps_db_effective, (err, plan.eps_db_effective)

    # class-size estimation: sample shares vs exact |class ∩ F| shares
    exact_masks = np.zeros((len(oracle), 24), bool)
    for i, s_ in enumerate(oracle):
        exact_masks[i, sorted(s_)] = True
    exact = np.array([
        pbec.member_mask(exact_masks, c.prefix, c.ext).sum()
        for c in plan.classes
    ], dtype=float)
    est = plan.est_sizes
    assert est.sum() > 0 and exact.sum() > 0
    share_err = np.abs(est / est.sum() - exact / exact.sum()).max()
    assert share_err <= 0.1, share_err


def test_planner_volumes_and_queues(small_plan):
    dense, oracle, shards, plan = small_plan
    # both schedules were priced; the chosen one is recorded
    assert plan.scheduler_used in ("lpt", "repl_min")
    assert plan.lpt_volume > 0 and plan.repl_volume > 0
    if plan.scheduler_used == "repl_min":
        assert plan.repl_volume < plan.lpt_volume
    queues = plan.shard_queues()
    assert sorted(c for q in queues for c in q) == list(range(len(plan.classes)))
    # queues drain heaviest-first so early rounds carry the scheduled weight
    for q in queues:
        sizes = [plan.est_sizes[c] for c in q]
        assert sizes == sorted(sizes, reverse=True)


# ---------------------------------------------------------------------------
# Executor: exactness under every backend/configuration
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("P", [2, 4])
def test_executor_exact_vmap(small_db, P):
    dense, db, minsup, oracle = small_db
    shards = fimi.shard_db(dense, P)
    res = cluster.execute(
        shards, 24,
        cluster.ClusterParams(planner=_planner_params()),
        jax.random.PRNGKey(1),
    )
    assert res.report.backend == "vmap"
    assert res.report.exchange_overflow == 0 and res.report.mine_overflow == 0
    assert res.table.to_dict() == oracle
    assert res.table.n_fis == len(oracle)


def test_executor_exact_under_rebalancing(small_db):
    """chunk=1 forces many rounds; a tiny FI sample forces skewed estimates;
    donations must fire and the result must stay exact."""
    dense, db, minsup, oracle = small_db
    shards = fimi.shard_db(dense, 4)
    res = cluster.execute(
        shards, 24,
        cluster.ClusterParams(
            planner=_planner_params(n_fi_sample=32),
            chunk=1, rebalance=True, skew_threshold=1.05,
        ),
        jax.random.PRNGKey(1),
    )
    assert res.report.n_rounds > 1
    assert len(res.report.donations) > 0
    assert res.table.to_dict() == oracle


def test_rebalancing_no_worse_than_static(small_db):
    """Same round structure, donations on vs off: modeled makespan must not
    regress, and the mined set is identical."""
    dense, db, minsup, oracle = small_db
    shards = fimi.shard_db(dense, 4)

    def run(rebalance):
        return cluster.execute(
            shards, 24,
            cluster.ClusterParams(
                planner=_planner_params(n_fi_sample=32, scheduler="lpt"),
                chunk=2, rebalance=rebalance,
            ),
            jax.random.PRNGKey(1),
        )

    static, rebal = run(False), run(True)
    assert static.table.to_dict() == rebal.table.to_dict() == oracle
    assert rebal.report.makespan_trips <= static.report.makespan_trips


def test_executor_exact_ragged_interpret():
    """Ragged item count (33 > one word) + interpret-mode Pallas kernels."""
    from repro.data.ibm_gen import IBMParams, generate_dense

    dense = generate_dense(IBMParams(
        n_tx=128, n_items=33, n_patterns=5, avg_pattern_len=4,
        avg_tx_len=6, seed=9,
    ))
    oracle = eclat.brute_force_fis(dense, int(np.ceil(0.1 * 128)))
    shards = fimi.shard_db(dense, 2)
    res = cluster.execute(
        shards, 33,
        cluster.ClusterParams(
            planner=_planner_params(
                min_support_rel=0.1, n_db_sample=64, n_fi_sample=64
            ),
            eclat=eclat.EclatConfig(
                max_out=4096, max_stack=1024, frontier_size=4
            ),
            force="interpret",
        ),
        jax.random.PRNGKey(5),
    )
    assert res.table.to_dict() == oracle


def test_executor_report_telemetry(small_plan):
    dense, oracle, shards, plan = small_plan
    res = cluster.execute(
        shards, 24,
        cluster.ClusterParams(planner=_planner_params()),
        jax.random.PRNGKey(3),
        plan=plan,
    )
    rep = res.report
    assert set(rep.phase_ms) == {"plan", "exchange", "mine", "merge"}
    assert rep.phase_ms["mine"] > 0
    assert rep.observed_loads.shape == (4,)
    assert rep.observed_loads.sum() > 0
    assert rep.imbalance >= 1.0
    assert 0.0 <= rep.estimation_error() <= 1.0
    assert rep.makespan_trips >= rep.observed_loads.max() / max(
        rep.n_rounds, 1
    )


# ---------------------------------------------------------------------------
# Rebalancer unit behavior
# ---------------------------------------------------------------------------


def test_ledger_rates_and_rebalance_bounds():
    ledger = cluster.LoadLedger(3)
    # shard 0's classes were under-estimated 4×; shard 1 spot-on; shard 2 idle
    ledger.record_round(np.array([40.0, 10.0, 0.0]), np.array([10.0, 10.0, 0.0]))
    rates = ledger.rates()
    assert rates[0] == pytest.approx(4.0)
    assert rates[1] == pytest.approx(1.0)
    assert rates[2] == pytest.approx(ledger.global_rate)  # no history → global

    est = np.array([8.0, 6.0, 4.0, 2.0, 1.0, 1.0])
    queues = [[0, 1, 2, 3], [4], [5]]
    moves = cluster.rebalance(
        queues, est, ledger, round_index=1,
        skew_threshold=1.1, max_donations=2,
    )
    assert 0 < len(moves) <= 2
    for m in moves:
        assert m.src == 0  # only the overloaded shard donates
        assert m.round_index == 1
    # donations come off the tail (cheapest pending classes first)
    donated = {m.class_id for m in moves}
    assert donated <= {2, 3}
    assert sorted(c for q in queues for c in q) == list(range(6))


def test_rebalance_noop_when_balanced():
    ledger = cluster.LoadLedger(2)
    queues = [[0], [1]]
    est = np.array([5.0, 5.0])
    moves = cluster.rebalance(queues, est, ledger, round_index=0)
    assert moves == []
    assert queues == [[0], [1]]


# ---------------------------------------------------------------------------
# shard_map parity — separate process with its own device count
# ---------------------------------------------------------------------------

_PARITY_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax
from repro import cluster
from repro.core import eclat, fimi
from repro.data.ibm_gen import IBMParams, generate_dense

dense = generate_dense(IBMParams(n_tx=256, n_items=16, n_patterns=6,
                                 avg_pattern_len=4, avg_tx_len=6, seed=11))
oracle = eclat.brute_force_fis(dense, int(np.ceil(0.1 * 256)))
shards = fimi.shard_db(dense, 4)
params = cluster.ClusterParams(
    planner=cluster.PlannerParams(min_support_rel=0.1, n_db_sample=128,
                                  n_fi_sample=64, alpha=0.7))
res = cluster.execute(shards, 16, params, jax.random.PRNGKey(2))
assert res.report.backend == "shard_map", res.report.backend
assert res.table.to_dict() == oracle, "cluster shard_map result != oracle"
fp = fimi.FimiParams(min_support_rel=0.1, n_db_sample=128, n_fi_sample=64,
                     alpha=0.7)
ref = fimi.run(fimi.shard_db(dense, 1), 16, fp, jax.random.PRNGKey(2),
               materialize=True)
assert res.table.to_dict() == ref.fi_dict, "cluster != single-device fimi.run"
print("CLUSTER_SHARD_MAP_PARITY_OK", len(oracle))
"""


def test_cluster_shard_map_parity_subprocess():
    """4 real host devices: shard_map executor == oracle == 1-device fimi.run
    (bit-exact supports; device-count flag isolated in a subprocess)."""
    r = subprocess.run(
        [sys.executable, "-c", _PARITY_SCRIPT],
        capture_output=True, text=True, timeout=900,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
        cwd=str(__import__("pathlib").Path(__file__).resolve().parents[1]),
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "CLUSTER_SHARD_MAP_PARITY_OK" in r.stdout


# ---------------------------------------------------------------------------
# StreamingMiner integration — distributed re-mines
# ---------------------------------------------------------------------------


def test_streaming_miner_with_cluster_mine_fn():
    """The executor plugs in as StreamingMiner.mine_fn: the initial mine and
    a forced re-mine are exact over the live window and the swap generation
    advances atomically."""
    from repro.data.ibm_gen import IBMParams, drifting_stream
    from repro.stream import StreamingMiner, StreamParams

    p = IBMParams(n_tx=512, n_items=20, n_patterns=6, avg_pattern_len=4,
                  avg_tx_len=7, seed=4)
    sp = StreamParams(
        n_blocks=2, block_tx=64, min_support_rel=0.15,
        eps=0.01, delta=0.2, check_every=1, cooldown_blocks=0, seed=4,
    )
    mine_fn = cluster.cluster_mine_fn(
        P=2,
        cluster_params=cluster.ClusterParams(
            planner=cluster.PlannerParams(n_db_sample=128, n_fi_sample=64),
            eclat=eclat.EclatConfig(max_out=4096, max_stack=1024,
                                    frontier_size=4),
        ),
        seed=4,
    )
    sm = StreamingMiner(sp, p.n_items, mine_fn=mine_fn)

    seen = []
    for dense_block, _ in drifting_stream(
        p, n_blocks=4, block_tx=64, breaks=(2,)
    ):
        ev = sm.admit(dense_block)
        seen.append(np.asarray(dense_block))
        if ev.remined:
            # distributed re-mine == brute force over the current window
            window_dense = np.concatenate(seen[-2:], axis=0)
            oracle = eclat.brute_force_fis(window_dense, sm.abs_minsup)
            idx = sm.engine.index
            got = {}
            masks = np.asarray(
                jnp.asarray(idx.masks[: idx.n_fis])
            )
            from repro.core import bitmap as bm

            dense_masks = np.asarray(
                bm.unpack_bool(jnp.asarray(masks), p.n_items)
            )
            for row, s in zip(dense_masks, np.asarray(idx.supports)):
                got[frozenset(np.nonzero(row)[0].tolist())] = int(s)
            assert got == oracle
            assert ev.generation == sm.engine.generation
    assert sm.engine is not None and sm.stats.remines >= 1
