"""Unit + property tests for the packed-bitmap substrate."""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st  # skips @given tests w/o hypothesis

import jax.numpy as jnp

from repro.core import bitmap as bm


@given(st.integers(1, 97), st.integers(1, 40), st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_pack_unpack_roundtrip(n, rows, seed):
    rng = np.random.default_rng(seed)
    dense = rng.random((rows, n)) < 0.4
    packed = bm.pack_bool(jnp.asarray(dense))
    back = np.asarray(bm.unpack_bool(packed, n))
    np.testing.assert_array_equal(back, dense)


@given(st.integers(0, 2**31 - 1), st.integers(1, 300))
@settings(max_examples=30, deadline=None)
def test_popcount_matches_numpy(seed, n):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 2**32, size=n, dtype=np.uint32)
    got = np.asarray(bm.popcount_u32(jnp.asarray(x)))
    want = np.array([bin(int(v)).count("1") for v in x])
    np.testing.assert_array_equal(got, want)


def test_support_monotonicity_property(small_db):
    """Thm 2.12: Supp(U) ≥ Supp(V) for U ⊊ V — on random chains."""
    dense, db, _, _ = small_db
    rng = np.random.default_rng(0)
    I = db.n_items
    for _ in range(25):
        size = rng.integers(1, 6)
        items = rng.choice(I, size=size, replace=False)
        prev = None
        for k in range(1, size + 1):
            mask = np.zeros(I, bool)
            mask[items[:k]] = True
            s = int(bm.support_of_itemset(db, jnp.asarray(mask)))
            # cross-check against numpy
            want = int(dense[:, items[:k]].all(axis=1).sum())
            assert s == want
            if prev is not None:
                assert s <= prev
            prev = s


def test_extension_supports_vs_dense(small_db):
    dense, db, _, _ = small_db
    got = np.asarray(bm.extension_supports(db.item_bits, db.all_tids()))
    np.testing.assert_array_equal(got, dense.sum(axis=0))


def test_pair_supports_vs_dense(small_db):
    dense, db, _, _ = small_db
    got = np.asarray(bm.pair_supports(db.item_bits, db.all_tids()))
    want = (dense.astype(np.int64).T @ dense.astype(np.int64))
    np.testing.assert_array_equal(got, want)


def test_tidlist_tail_masking(thesis_db):
    """all_tids masks bits beyond n_tx (15 tx → 17 junk bits must be 0)."""
    tid = np.asarray(thesis_db.all_tids())
    assert bm.popcount_u32(jnp.asarray(tid)).sum() == thesis_db.n_tx


def test_is_subset_packed():
    a = bm.pack_bool(jnp.asarray([[True, False, True, False] * 10]))
    b = bm.pack_bool(jnp.asarray([[True, True, True, False] * 10]))
    assert bool(bm.is_subset_packed(a, b)[0])
    assert not bool(bm.is_subset_packed(b, a)[0])
