"""Observability layer: histogram percentiles vs the numpy nearest-rank
oracle, registry snapshot shape, tracer thread-safety + Chrome trace-event
export, subsystem instrumentation (reader/retry/cache/cluster report), run
records, and the report CLI's regression gates."""
import json
import math
import os
import subprocess
import sys
import threading
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path

import numpy as np
import pytest

from repro.launch import obs_report
from repro.obs import metrics as obs_metrics
from repro.obs import runlog
from repro.obs import trace as obs_trace

REPO = Path(__file__).resolve().parents[1]


@pytest.fixture(autouse=True)
def _fresh_obs():
    """Every test starts (and leaves) with clean global registry/tracer."""
    obs_metrics.reset()
    obs_trace.TRACER.disable()
    obs_trace.TRACER.clear()
    yield
    obs_metrics.reset()
    obs_trace.TRACER.disable()
    obs_trace.TRACER.clear()


# ---------------------------------------------------------------------------
# Histogram: log-bucketed percentiles vs the exact numpy nearest-rank oracle
# ---------------------------------------------------------------------------


def _oracle(samples, q):
    return float(np.percentile(np.asarray(samples, float), q,
                               method="nearest"))


@pytest.mark.parametrize(
    "samples",
    [
        # uniform: adjacent ranks are close, buckets dominate the error
        np.random.default_rng(0).uniform(0.1, 10.0, size=1000).tolist(),
        # lognormal: 6 decades of dynamic range in one histogram
        np.random.default_rng(1).lognormal(0.0, 2.5, size=2000).tolist(),
        # bimodal with a 1000x gap right at the median rank — the adversarial
        # case for any bucketed sketch (both sides use round-half-even, so
        # the nearest rank is deterministic on both)
        [1.0] * 50 + [1000.0] * 50,
        # heavily skewed bimodal: p50 on the low mode, p95/p99 on the high
        [1.0] * 90 + [1000.0] * 10,
        # constant stream
        [3.7] * 64,
        # two samples, extreme spread
        [1e-6, 1e6],
    ],
    ids=["uniform", "lognormal", "bimodal-50", "bimodal-90", "constant",
         "pair"],
)
def test_histogram_percentiles_match_numpy(samples):
    h = obs_metrics.Histogram("t/lat_ms", growth=1.08)
    for v in samples:
        h.record(v)
    # documented bound: within a sqrt(growth) factor of the exact
    # nearest-rank percentile (bucket midpoint, clamped to [min, max])
    factor = math.sqrt(1.08) * (1 + 1e-9)
    for q in (0, 50, 95, 99, 100):
        want = _oracle(samples, q)
        got = h.percentile(q)
        assert got is not None
        assert want / factor <= got <= want * factor, (
            f"q={q}: got {got}, oracle {want}"
        )
    assert h.count == len(samples)
    assert h.sum == pytest.approx(sum(samples), rel=1e-9)


def test_histogram_single_sample_and_empty():
    h = obs_metrics.Histogram("t/x_s")
    assert h.percentile(50) is None
    s = h.summary()
    assert s["count"] == 0
    assert s["mean"] is None and s["p50"] is None and s["max"] is None
    h.record(42.0)
    # one sample: every percentile IS that sample, exactly
    for q in (0, 50, 99, 100):
        assert h.percentile(q) == 42.0
    s = h.summary()
    assert s["count"] == 1 and s["min"] == s["max"] == 42.0


def test_histogram_underflow_and_bad_samples():
    h = obs_metrics.Histogram("t/x_s")
    for v in [0.0, 0.0, 0.0, 5.0]:
        h.record(v)
    # zeros land in the underflow bucket; low ranks report the exact min
    assert h.percentile(50) == 0.0
    assert h.percentile(99) == pytest.approx(5.0, rel=0.05)
    with pytest.raises(ValueError):
        h.record(-1.0)
    with pytest.raises(ValueError):
        h.record(float("nan"))


# ---------------------------------------------------------------------------
# Registry: counters/gauges, canonical snapshot shape, typed names
# ---------------------------------------------------------------------------


def test_registry_snapshot_shape_and_reset():
    reg = obs_metrics.MetricsRegistry()
    reg.counter("a/events").inc()
    reg.counter("a/events").inc(4)
    reg.gauge("a/level").set(2.5)
    reg.gauge("a/peak").update_max(7.0)
    reg.gauge("a/peak").update_max(3.0)     # high-water keeps 7
    reg.histogram("a/lat_ms").record(1.0)
    snap = reg.snapshot()
    assert set(snap) == {"counters", "gauges", "histograms"}
    assert snap["counters"] == {"a/events": 5}
    assert snap["gauges"] == {"a/level": 2.5, "a/peak": 7.0}
    assert set(snap["histograms"]["a/lat_ms"]) == {
        "count", "sum", "mean", "min", "max", "p50", "p95", "p99"
    }
    # snapshot is JSON-clean by construction (what runlog writes verbatim)
    json.dumps(snap)
    assert reg.names() == ["a/events", "a/lat_ms", "a/level", "a/peak"]
    reg.reset()
    assert reg.snapshot() == {"counters": {}, "gauges": {}, "histograms": {}}


def test_registry_rejects_type_mismatch():
    reg = obs_metrics.MetricsRegistry()
    reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")
    with pytest.raises(TypeError):
        reg.histogram("x")


def test_counter_thread_safety():
    reg = obs_metrics.MetricsRegistry()
    c = reg.counter("n")

    def hammer():
        for _ in range(2000):
            c.inc()

    with ThreadPoolExecutor(8) as ex:
        list(ex.map(lambda _: hammer(), range(8)))
    assert c.value == 8 * 2000


# ---------------------------------------------------------------------------
# Tracer: disabled fast path, nesting, export, thread-safety
# ---------------------------------------------------------------------------


def _assert_chrome_trace(obj):
    """Structural validity of a Chrome trace-event object (what Perfetto
    and chrome://tracing require to render)."""
    assert isinstance(obj, dict) and isinstance(obj["traceEvents"], list)
    for ev in obj["traceEvents"]:
        assert ev["ph"] in ("X", "i", "M", "C")
        assert isinstance(ev["name"], str)
        assert isinstance(ev["pid"], int) and isinstance(ev["tid"], int)
        if ev["ph"] == "X":
            assert isinstance(ev["ts"], (int, float))
            assert isinstance(ev["dur"], (int, float)) and ev["dur"] >= 0
        if ev["ph"] == "M":
            assert ev["name"] == "thread_name"
            assert isinstance(ev["args"]["name"], str)
        if ev["ph"] == "C":
            # counter samples: every lane value must be numeric
            assert ev["args"] and all(
                isinstance(v, (int, float)) for v in ev["args"].values())


def test_tracer_disabled_is_inert():
    tr = obs_trace.Tracer(enabled=False)
    # the disabled span is one shared object: no per-call allocation
    assert tr.span("a") is tr.span("b") is obs_trace._NULL_SPAN
    with tr.span("a"):
        pass
    tr.instant("mark")
    tr.add_span("lane", 0.0, 1.0, track="shard0")
    assert tr.n_events == 0
    # sync() must return the value untouched — no jax import, no block
    sentinel = object()
    assert tr.sync(sentinel) is sentinel


def test_tracer_span_nesting_and_export_round_trip():
    tr = obs_trace.Tracer(enabled=True)
    with tr.span("outer", P=4):
        with tr.span("inner"):
            pass
        tr.instant("tick", round=1)
    tr.add_span("modeled", 0.0, 0.25, track="shard1", args={"trips": 9})
    out = json.loads(json.dumps(tr.export()))   # byte round-trip
    _assert_chrome_trace(out)
    evs = {e["name"]: e for e in out["traceEvents"] if e["ph"] != "M"}
    assert set(evs) == {"outer", "inner", "tick", "modeled"}
    # nesting by time containment: inner ⊆ outer on the same track
    outer, inner = evs["outer"], evs["inner"]
    assert outer["tid"] == inner["tid"]
    assert outer["ts"] <= inner["ts"]
    assert outer["ts"] + outer["dur"] >= inner["ts"] + inner["dur"]
    assert outer["args"] == {"P": 4}
    assert evs["tick"]["ph"] == "i" and evs["tick"]["args"] == {"round": 1}
    # the virtual track got a thread_name metadata record
    tracks = {e["args"]["name"] for e in out["traceEvents"]
              if e["ph"] == "M"}
    assert "shard1" in tracks
    assert evs["modeled"]["dur"] == pytest.approx(0.25e6)  # seconds → µs


def test_tracer_thread_safety_under_concurrent_spans():
    tr = obs_trace.Tracer(enabled=True)

    def worker(i):
        for k in range(50):
            with tr.span(f"w{i}", k=k):
                tr.instant(f"m{i}")

    with ThreadPoolExecutor(8) as ex:
        list(ex.map(worker, range(8)))
    with tr.span("main"):
        pass
    assert tr.n_events == 8 * 50 * 2 + 1
    out = tr.export()
    _assert_chrome_trace(out)
    # every recording thread is named in the metadata
    named_tids = {e["tid"] for e in out["traceEvents"] if e["ph"] == "M"}
    used_tids = {e["tid"] for e in out["traceEvents"] if e["ph"] != "M"}
    assert used_tids <= named_tids
    tr.clear()
    assert tr.n_events == 0


def test_tracer_enable_disable_cycle():
    tr = obs_trace.Tracer()
    with tr.span("off"):
        pass
    tr.enable()
    with tr.span("on"):
        pass
    tr.disable()
    with tr.span("off2"):
        pass
    names = [e["name"] for e in tr.export()["traceEvents"]
             if e["ph"] == "X"]
    assert names == ["on"]


# ---------------------------------------------------------------------------
# Subsystem instrumentation: reader (its prefetch worker thread records
# concurrently with the consumer), retry, cache stats, cluster report
# ---------------------------------------------------------------------------


def test_block_reader_metrics_and_prefetch_thread(tmp_path):
    from repro.store import BlockReader, StoreWriter

    rng = np.random.default_rng(7)
    dense = rng.random((64, 24)) < 0.3
    w = StoreWriter(str(tmp_path / "st"), n_items=24, block_tx=16)
    for off in range(0, 64, 16):
        w.append_dense(dense[off:off + 16])
    store = w.close()

    tr = obs_trace.TRACER
    tr.enable()
    reader = BlockReader(store, host_budget_blocks=2)
    n = 0
    for _i, _off, blk, _rows in reader.device_blocks():
        with tr.span("consume", block=n):
            np.asarray(blk)     # force the device value
        n += 1
    assert n == store.n_blocks
    snap = obs_metrics.snapshot()
    # the consumer thread recorded the stall histogram + block counter while
    # the prefetch worker recorded the residency high-water gauge
    assert snap["counters"]["store/blocks_read"] == store.n_blocks
    assert snap["histograms"]["store/prefetch_stall_s"]["count"] == \
        store.n_blocks
    assert snap["gauges"]["store/host_bytes_peak"] > 0
    assert snap["gauges"]["store/host_bytes_peak"] == reader.peak_host_bytes
    _assert_chrome_trace(tr.export())


def test_retry_policy_metrics():
    from repro.store.retry import RetriesExhausted, RetryPolicy

    pol = RetryPolicy(attempts=3, sleep=lambda s: None)
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("transient")
        return "ok"

    assert pol.call(flaky) == "ok"
    snap = obs_metrics.snapshot()
    assert snap["counters"]["store/retry/attempts"] == 3
    assert snap["counters"]["store/retry/retried_errors"] == 2
    assert "store/retry/exhausted" not in snap["counters"]

    def broken():
        raise OSError("persistent")

    with pytest.raises(RetriesExhausted):
        RetryPolicy(attempts=2, sleep=lambda s: None).call(broken)
    snap = obs_metrics.snapshot()
    assert snap["counters"]["store/retry/attempts"] == 3 + 2
    assert snap["counters"]["store/retry/exhausted"] == 1


def test_cache_stats_thin_views_and_global_mirror():
    from repro.serve.cache import CacheStats

    s = CacheStats()
    for _ in range(3):
        s.hit()
    s.miss()
    s.eviction()
    s.invalidation()
    assert (s.hits, s.misses, s.evictions, s.invalidations) == (3, 1, 1, 1)
    assert s.lookups == 4
    assert s.hit_rate == pytest.approx(0.75)
    assert s.as_dict()["hits"] == 3
    snap = s.snapshot()
    assert set(snap) == {"counters", "gauges", "histograms"}
    assert snap["counters"]["serve/cache/hits"] == 3
    # every event was mirrored into the process-global registry
    g = obs_metrics.snapshot()["counters"]
    assert g["serve/cache/hits"] == 3
    assert g["serve/cache/misses"] == 1
    # a second cache adds to the global mirror but keeps its own counts
    s2 = CacheStats()
    s2.hit()
    assert s2.hits == 1 and s.hits == 3
    assert obs_metrics.snapshot()["counters"]["serve/cache/hits"] == 4
    # backing a CacheStats with the global registry must not double-count
    obs_metrics.reset()
    sg = CacheStats(registry=obs_metrics.registry())
    sg.hit()
    assert sg.hits == 1
    assert obs_metrics.snapshot()["counters"]["serve/cache/hits"] == 1


def test_cluster_report_snapshot_and_emit():
    from repro.cluster.executor import ClusterReport, RoundStats

    rounds = [
        RoundStats(0, [2, 1], np.array([10, 20], np.int64),
                   np.array([1.0, 2.0]), 1.5, []),
        RoundStats(1, [1, 0], np.array([5, 0], np.int64),
                   np.array([1.0, 0.0]), 1.2, []),
    ]
    rep = ClusterReport(
        P=2, backend="vmap", rounds=rounds,
        phase_ms={"plan": 1.0, "exchange": 2.0, "mine": 8.0, "merge": 0.5},
        est_loads=np.array([1.0, 2.0]),
        observed_loads=np.array([15.0, 20.0]),
        donations=[], exchange_overflow=0, mine_overflow=0,
    )
    snap = rep.snapshot()
    assert set(snap) == {"counters", "gauges", "histograms"}
    assert snap["counters"]["cluster/rounds"] == 2
    assert snap["gauges"]["cluster/makespan_trips"] == 25.0   # 20 + 5
    assert snap["gauges"]["cluster/imbalance"] == rep.imbalance
    assert snap["gauges"]["cluster/phase_ms/mine"] == 8.0
    for p in range(2):
        assert f"cluster/shard{p}/est_load" in snap["gauges"]
        assert f"cluster/shard{p}/obs_load" in snap["gauges"]
    h = snap["histograms"]["cluster/round_makespan_trips"]
    assert h["count"] == 2 and h["max"] == 20.0 and h["min"] == 5.0
    # emit() replays the same numbers into a registry
    reg = obs_metrics.MetricsRegistry()
    rep.emit(reg)
    got = reg.snapshot()
    assert got["counters"] == snap["counters"]
    assert got["gauges"] == snap["gauges"]
    assert got["histograms"]["cluster/round_makespan_trips"]["count"] == 2


# ---------------------------------------------------------------------------
# Run records + report CLI
# ---------------------------------------------------------------------------


def _make_run(run_dir, wall=2.0, stall_scale=1.0):
    """A synthetic but structurally complete run record."""
    reg = obs_metrics.registry()
    reg.counter("fimi/runs").inc()
    reg.counter("store/blocks_read").inc(8)
    reg.gauge("fimi/n_fis").set(123.0)
    reg.gauge("cluster/phase_ms/mine").set(40.0 * stall_scale)
    h = reg.histogram("store/prefetch_stall_s")
    for v in (0.01, 0.02, 0.03, 0.5):
        h.record(v * stall_scale)
    tr = obs_trace.TRACER
    tr.enable()
    with tr.span("fimi/phase4_mine"):
        pass
    log = runlog.RunLog(str(run_dir), "testrun", {"support": 0.1})
    log.event("round", index=0, trips=[3, 4])
    log.event("round", index=1, trips=np.array([5, 6]))
    log.finish(metrics_snapshot=obs_metrics.snapshot(), tracer=tr,
               mine_wall_s=wall, n_fis=123)
    tr.disable()
    return str(run_dir)


def test_runlog_round_trip(tmp_path):
    d = _make_run(tmp_path / "run")
    run = runlog.load_run(d)
    man = run["manifest"]
    assert man["name"] == "testrun"
    assert man["config"] == {"support": 0.1}
    assert man["mine_wall_s"] == 2.0 and man["n_fis"] == 123
    assert isinstance(man["wall_s"], float)
    assert [e["kind"] for e in run["events"]] == ["round", "round"]
    assert run["events"][1]["trips"] == [5, 6]       # numpy made jsonable
    assert run["events"][0]["t"] <= run["events"][1]["t"]
    assert run["metrics"]["counters"]["fimi/runs"] == 1
    _assert_chrome_trace(run["trace"])
    with pytest.raises(FileNotFoundError):
        runlog.load_run(str(tmp_path / "nope"))


def test_obs_report_summary_and_self_diff(tmp_path, capsys):
    d = _make_run(tmp_path / "run")
    assert obs_report.main(["summary", d]) == 0
    out = capsys.readouterr().out
    assert "testrun" in out and "fimi/runs" in out
    assert "store/prefetch_stall_s" in out and "fimi/phase4_mine" in out
    # a run never regresses against itself
    assert obs_report.main(["diff", d, d]) == 0


def test_obs_report_diff_gates_injected_slowdown(tmp_path, capsys):
    a = _make_run(tmp_path / "a")
    b = str(tmp_path / "b")
    assert obs_report.main(["inject-slowdown", a, b, "--factor", "1.5"]) == 0
    # time-like metrics 1.5x slower: the 20% gate must fail...
    assert obs_report.main(["diff", a, b, "--threshold", "0.2"]) == 1
    out = capsys.readouterr().out
    assert "REGRESSION" in out
    assert "mine_wall_s" in out
    # ...a loose gate passes, and a speedup never gates
    assert obs_report.main(["diff", a, b, "--threshold", "0.6"]) == 0
    assert obs_report.main(["diff", b, a, "--threshold", "0.2"]) == 0
    # non-time metrics (counts, sizes) must never gate even when changed
    assert runlog.load_run(b)["metrics"]["gauges"]["fimi/n_fis"] == 123.0


def test_obs_report_diff_scales_every_time_family(tmp_path):
    a = _make_run(tmp_path / "a")
    b = str(tmp_path / "b")
    obs_report.main(["inject-slowdown", a, b, "--factor", "2.0"])
    ta = obs_report._time_metrics(runlog.load_run(a))
    tb = obs_report._time_metrics(runlog.load_run(b))
    assert set(ta) == set(tb) and len(ta) >= 3   # wall, gauge, hist p95
    for k in ta:
        assert tb[k] == pytest.approx(2.0 * ta[k], rel=1e-6), k


def test_obs_report_baseline_gate(tmp_path):
    bench_ok = tmp_path / "BENCH_ok.json"
    bench_ok.write_text(json.dumps(
        {"obs_overhead_streamed": 1.02, "mine_ms": 120.0}
    ))
    bench_bad = tmp_path / "BENCH_bad.json"
    bench_bad.write_text(json.dumps(
        {"nested": {"checksum_slowdown": 1.4}}
    ))
    assert obs_report.main(
        ["baseline", "--bench", str(bench_ok), "--threshold", "0.05"]
    ) == 0
    assert obs_report.main(
        ["baseline", "--bench", str(bench_bad), "--threshold", "0.05"]
    ) == 1
    # both at once: one bad file fails the whole gate
    assert obs_report.main(
        ["baseline", "--bench", str(bench_ok), "--bench", str(bench_bad)]
    ) == 1
    # --match narrows the gated keys: the bad slowdown key is out of scope
    assert obs_report.main(
        ["baseline", "--bench", str(bench_bad), "--match", "overhead"]
    ) == 0


def test_obs_report_unreadable_record_exits_2(tmp_path):
    with pytest.raises(SystemExit) as e:
        obs_report.main(["summary", str(tmp_path / "missing")])
    assert e.value.code == 2


def test_obs_report_is_jax_free():
    """The layering rule: the report CLI must import without jax."""
    r = subprocess.run(
        [sys.executable, "-c",
         "import sys; sys.modules['jax'] = None\n"
         "from repro.launch import obs_report\n"
         "from repro.obs import metrics, runlog\n"
         "print('JAXFREE_OK')"],
        capture_output=True, text=True, timeout=120,
        env={**os.environ, "PYTHONPATH": "src"}, cwd=str(REPO),
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "JAXFREE_OK" in r.stdout


def test_obs_report_summary_format_json(tmp_path, capsys):
    d = _make_run(tmp_path / "run")
    assert obs_report.main(["summary", d, "--format", "json"]) == 0
    out = json.loads(capsys.readouterr().out)
    assert out["name"] == "testrun"
    assert out["counters"]["fimi/runs"] == 1
    assert out["gauges"]["fimi/n_fis"] == 123.0
    assert out["histograms"]["store/prefetch_stall_s"]["count"] == 4
    assert [e["kind"] for e in out["events"]] == ["round", "round"]
    assert any(s["name"] == "fimi/phase4_mine" for s in out["spans"])


def test_obs_report_summary_format_markdown(tmp_path, capsys):
    d = _make_run(tmp_path / "run")
    assert obs_report.main(["summary", d, "--format", "markdown"]) == 0
    out = capsys.readouterr().out
    assert "### run `testrun`" in out
    assert "| fimi/runs | 1 |" in out
    assert "store/prefetch_stall_s" in out
    assert "fimi/phase4_mine" in out
    # same digest, three renderings: text remains the default
    assert obs_report.main(["summary", d]) == 0
    assert "### run" not in capsys.readouterr().out


# ---------------------------------------------------------------------------
# Crash-safe sessions: a killed run still writes a loadable partial record
# ---------------------------------------------------------------------------

_VICTIM = """\
import sys, time
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.session import ObsSession

s = ObsSession(sys.argv[1], "victim", {"x": 1}, trace_on=True)
obs_metrics.registry().counter("victim/progress").inc(3)
with obs_trace.TRACER.span("victim/work"):
    pass
s.event("tick", n=1)
print("READY", flush=True)
@TAIL@
"""


def _spawn_victim(tmp_path, tail):
    run_dir = tmp_path / "rec"
    proc = subprocess.Popen(
        [sys.executable, "-c", _VICTIM.replace("@TAIL@", tail), str(run_dir)],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
        env={**os.environ, "PYTHONPATH": "src"}, cwd=str(REPO),
    )
    assert proc.stdout.readline().strip() == "READY"
    return run_dir, proc


def _assert_partial_record(run_dir, reason):
    man = json.loads((run_dir / "manifest.json").read_text())
    assert man["name"] == "victim"
    assert man["partial"] is True and man["partial_reason"] == reason
    assert isinstance(man["wall_s"], float)
    metrics = json.loads((run_dir / "metrics.json").read_text())
    assert metrics["counters"]["victim/progress"] == 3
    trace = json.loads((run_dir / "trace.json").read_text())
    _assert_chrome_trace(trace)
    assert "victim/work" in {e["name"] for e in trace["traceEvents"]}
    run = runlog.load_run(str(run_dir))
    assert [e["kind"] for e in run["events"]] == ["tick"]


def test_obs_session_sigterm_flushes_partial_record(tmp_path):
    run_dir, proc = _spawn_victim(tmp_path, "time.sleep(120)")
    proc.terminate()                      # SIGTERM mid-run
    proc.wait(timeout=60)
    # the chained default disposition preserves the conventional kill status
    assert proc.returncode == -15
    _assert_partial_record(run_dir, "sigterm")


def test_obs_session_atexit_flushes_partial_record(tmp_path):
    # the victim falls off the end of the script without calling finish()
    run_dir, proc = _spawn_victim(tmp_path, "pass")
    proc.wait(timeout=60)
    assert proc.returncode == 0
    _assert_partial_record(run_dir, "atexit")


def test_obs_session_finish_seals_and_disarms_crash_hooks(tmp_path):
    from repro.obs.session import ObsSession

    s = ObsSession(str(tmp_path / "rec"), "ok", {}, trace_on=False)
    obs_metrics.registry().counter("ok/n").inc()
    s.finish(n_fis=7)
    man = json.loads((tmp_path / "rec" / "manifest.json").read_text())
    assert "partial" not in man and man["n_fis"] == 7
    # the atexit hook is unregistered: simulating it must not resurrect
    # the partial flag on the sealed record
    s._atexit_flush()
    man = json.loads((tmp_path / "rec" / "manifest.json").read_text())
    assert "partial" not in man


# ---------------------------------------------------------------------------
# Driver smoke: --trace produces a loadable record end to end
# ---------------------------------------------------------------------------


def test_mine_driver_trace_smoke(tmp_path):
    run_dir = tmp_path / "rec"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.mine",
         "--db", "T0.25I0.016P6PL4TL6", "--support", "0.15", "-P", "2",
         "--trace", str(run_dir)],
        capture_output=True, text=True, timeout=900,
        env={**os.environ, "PYTHONPATH": "src"}, cwd=str(REPO),
    )
    assert r.returncode == 0, r.stderr[-3000:]
    trace = json.loads((run_dir / "trace.json").read_text())
    _assert_chrome_trace(trace)
    names = {e["name"] for e in trace["traceEvents"] if e["ph"] == "X"}
    assert {"fimi/phase1_sample", "fimi/phase2_partition",
            "fimi/phase3_exchange", "fimi/phase4_mine"} <= names
    man = json.loads((run_dir / "manifest.json").read_text())
    assert man["name"] == "mine" and "mine_wall_s" in man
    metrics = json.loads((run_dir / "metrics.json").read_text())
    assert metrics["counters"]["fimi/runs"] == 1
    assert "fimi/load/estimation_error" in metrics["gauges"]
    assert "fimi/frontier_occupancy" in metrics["histograms"]
    assert any(k.startswith("fimi/shard") for k in metrics["gauges"])
    # the record is diffable against itself through the CLI
    assert obs_report.main(["diff", str(run_dir), str(run_dir)]) == 0


# ---------------------------------------------------------------------------
# Tracer event cap: drop-oldest, dropped-event accounting, truncation note
# ---------------------------------------------------------------------------


def test_tracer_cap_drops_oldest_and_counts():
    tr = obs_trace.Tracer(enabled=True, max_events=5)
    for i in range(8):
        tr.instant(f"ev{i}")
    assert tr.n_events == 5
    assert tr.dropped_events == 3
    out = tr.export()
    names = [e["name"] for e in out["traceEvents"] if e["ph"] == "i"]
    assert names == ["ev3", "ev4", "ev5", "ev6", "ev7"]   # a suffix
    assert out["truncated_events"] == 3
    # the drop is visible as a metric too (the doctor's evidence key)
    snap = obs_metrics.snapshot()
    assert snap["counters"]["trace/dropped_events"] == 3


def test_tracer_uncapped_export_has_no_truncation_note():
    tr = obs_trace.Tracer(enabled=True, max_events=100)
    with tr.span("a"):
        pass
    out = tr.export()
    assert "truncated_events" not in out
    assert tr.dropped_events == 0


def test_tracer_set_max_events_recaps_keeping_newest():
    tr = obs_trace.Tracer(enabled=True, max_events=100)
    for i in range(10):
        tr.instant(f"ev{i}")
    tr.set_max_events(4)
    assert tr.max_events == 4 and tr.n_events == 4
    assert tr.dropped_events == 6
    names = [e["name"] for e in tr.export()["traceEvents"]
             if e["ph"] == "i"]
    assert names == ["ev6", "ev7", "ev8", "ev9"]


def test_tracer_clear_resets_dropped():
    tr = obs_trace.Tracer(enabled=True, max_events=2)
    for i in range(5):
        tr.instant(f"ev{i}")
    assert tr.dropped_events == 3
    tr.clear()
    assert tr.dropped_events == 0 and tr.n_events == 0


# ---------------------------------------------------------------------------
# summary: exclusive self-time via the critpath DAG (one implementation)
# ---------------------------------------------------------------------------


def test_summary_spans_carry_exclusive_self_time(tmp_path, capsys):
    reg = obs_metrics.registry()
    reg.counter("fimi/runs").inc()
    tr = obs_trace.TRACER
    tr.enable()
    with tr.span("outer"):
        with tr.span("inner"):
            pass
    log = runlog.RunLog(str(tmp_path / "run"), "selftime", {})
    log.finish(metrics_snapshot=obs_metrics.snapshot(), tracer=tr)
    tr.disable()

    assert obs_report.main(
        ["summary", str(tmp_path / "run"), "--format", "json"]) == 0
    out = json.loads(capsys.readouterr().out)
    spans = {s["name"]: s for s in out["spans"]}
    assert set(spans) == {"outer", "inner"}
    for s in out["spans"]:
        assert set(s) >= {"name", "total_ms", "self_ms", "count"}
    # the child's time is excluded from the parent's self time
    assert spans["outer"]["self_ms"] == pytest.approx(
        spans["outer"]["total_ms"] - spans["inner"]["total_ms"], abs=0.5)
    assert spans["inner"]["self_ms"] == pytest.approx(
        spans["inner"]["total_ms"])

    # and the markdown table grew the column
    assert obs_report.main(
        ["summary", str(tmp_path / "run"), "--format", "markdown"]) == 0
    assert "self ms" in capsys.readouterr().out
