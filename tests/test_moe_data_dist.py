"""MoE dispatch correctness + LPT expert placement; data pipeline; sharding
rules; HLO collective parser; shard_map parity (subprocess, own devices)."""
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.core.schedule import loads_of
from repro.data.lm_pipeline import SyntheticLM
from repro.distributed import hlo as hlo_mod
from repro.models import moe as moe_mod
from repro.models.layers import init_params

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


def _moe(E=8, k=2, d=32, f=16, cf=8.0):
    m = MoEConfig(n_experts=E, top_k=k, expert_d_ff=f, capacity_factor=cf)
    p = init_params(moe_mod.moe_specs(d, m), KEY, jnp.float32)
    return m, p


def test_moe_matches_dense_oracle():
    """With ample capacity, dispatch-combine == per-token dense computation."""
    m, p = _moe()
    x = jax.random.normal(KEY, (2, 12, 32), jnp.float32)
    y, aux = moe_mod.moe_forward(p, x, m)
    assert int(aux["dropped"]) == 0

    # oracle: loop over tokens/experts
    xt = np.asarray(x).reshape(-1, 32)
    logits = xt @ np.asarray(p["router"])
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    want = np.zeros_like(xt)
    for n in range(xt.shape[0]):
        top = np.argsort(-probs[n])[: m.top_k]
        w = probs[n][top] / probs[n][top].sum()
        for e, wi in zip(top, w):
            g = xt[n] @ np.asarray(p["w_gate"][e])
            u = xt[n] @ np.asarray(p["w_up"][e])
            silu = g / (1 + np.exp(-g)) * u
            want[n] += wi * (silu @ np.asarray(p["w_down"][e]))
    np.testing.assert_allclose(
        np.asarray(y).reshape(-1, 32), want, rtol=2e-3, atol=2e-4
    )


def test_moe_capacity_drops_counted():
    m, p = _moe(cf=0.05)
    x = jax.random.normal(KEY, (2, 64, 32), jnp.float32)
    y, aux = moe_mod.moe_forward(p, x, m)
    assert int(aux["dropped"]) > 0
    assert np.isfinite(np.asarray(y)).all()


def test_lpt_expert_placement_balances_load():
    """Paper bridge: skewed sampled loads → LPT placement balances EP ranks
    far better than the naive modulo striping."""
    rng = np.random.default_rng(0)
    E, R = 64, 8
    load = rng.zipf(1.5, E).astype(float)
    perm = moe_mod.lpt_expert_permutation(load, R)
    assert sorted(perm) == list(range(E))
    rank_load_lpt = loads_of(load, perm // (E // R), R)
    rank_load_naive = loads_of(load, np.arange(E) % R, R)
    assert rank_load_lpt.max() <= rank_load_naive.max()
    # Graham bound relative to the LPT lower bound max(mean, heaviest expert):
    opt_lb = max(load.sum() / R, load.max())
    assert rank_load_lpt.max() <= (4.0 / 3.0) * opt_lb + 1e-9


def test_expert_permutation_preserves_function():
    """Permuting expert weights + routing indices is a no-op on outputs."""
    m, p = _moe()
    x = jax.random.normal(KEY, (1, 16, 32), jnp.float32)
    y0, _ = moe_mod.moe_forward(p, x, m)
    perm = np.asarray(moe_mod.lpt_expert_permutation(np.arange(m.n_experts) + 1.0, 4))
    p2 = moe_mod.apply_expert_permutation(p, perm)
    y1, _ = moe_mod.moe_forward(p2, x, m, expert_perm=jnp.asarray(perm))
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------


def test_pipeline_deterministic_and_resumable():
    p1 = SyntheticLM(vocab=997, seq_len=64, global_batch=8, seed=7)
    batches = [p1.next_batch() for _ in range(5)]
    # resume from state 3 → identical batches
    p2 = SyntheticLM(vocab=997, seq_len=64, global_batch=8, seed=7)
    p2.load_state_dict({"seed": 7, "step": 3})
    np.testing.assert_array_equal(p2.next_batch()["tokens"], batches[3]["tokens"])
    np.testing.assert_array_equal(p2.next_batch()["tokens"], batches[4]["tokens"])


def test_pipeline_host_sharding_disjoint():
    a = SyntheticLM(997, 64, 8, seed=1, n_hosts=2, host_id=0).batch_at(0)
    b = SyntheticLM(997, 64, 8, seed=1, n_hosts=2, host_id=1).batch_at(0)
    assert a["tokens"].shape == (4, 64)
    assert not np.array_equal(a["tokens"], b["tokens"])


# ---------------------------------------------------------------------------
# Sharding rules + HLO parser
# ---------------------------------------------------------------------------


def test_spec_for_divisibility_and_conflicts():
    from jax.sharding import PartitionSpec as PS

    from repro.distributed.sharding import default_rules, spec_for

    # use a fake mesh shape via dict-like: spec_for only reads mesh.shape
    class FakeMesh:
        shape = {"data": 16, "model": 16}

    rules = default_rules(multi_pod=False)
    # heads=24 not divisible by 16 → dropped; ffn=8192 divisible → model
    s = spec_for((3072, 24, 128), ("embed", "heads", "head_dim"), FakeMesh(), rules)
    assert s == PS("data")
    s = spec_for((3072, 8192), ("embed", "ffn"), FakeMesh(), rules)
    assert s == PS("data", "model")
    # conflict: vocab and ffn both want model → second drops
    s = spec_for((4096, 8192), ("vocab", "ffn"), FakeMesh(), rules)
    assert s == PS("model")


def test_hlo_collective_parser():
    txt = textwrap.dedent("""\
      %ag = bf16[16,1024]{1,0} all-gather(bf16[1,1024]{1,0} %p), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}
      %ar = f32[128]{0} all-reduce(f32[128]{0} %x), replica_groups=[4,16]<=[64] to_apply=%add
      %rs = f32[8]{0} reduce-scatter(f32[128]{0} %y), replica_groups={{0,1}}
      %done = f32[8]{0} all-reduce-done(f32[8]{0} %h)
    """)
    colls = hlo_mod.parse_collectives(txt, 64)
    ops = {c.op: c for c in colls}
    assert ops["all-gather"].bytes_result == 16 * 1024 * 2
    assert ops["all-gather"].group_size == 4
    assert ops["all-reduce"].group_size == 16
    s = hlo_mod.collective_summary(txt, 64)
    assert s["count"] == 3  # the -done line is excluded (paired with -start)
    assert s["total_wire_bytes_per_device"] > 0


# ---------------------------------------------------------------------------
# shard_map parity — separate process with its own device count
# ---------------------------------------------------------------------------

_PARITY_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax
from repro.core import fimi, eclat
from repro.data.ibm_gen import IBMParams, generate_dense
from repro.launch.mesh import make_miner_mesh

dense = generate_dense(IBMParams(n_tx=256, n_items=16, n_patterns=6,
                                 avg_pattern_len=4, avg_tx_len=6, seed=11))
oracle = eclat.brute_force_fis(dense, int(np.ceil(0.1 * 256)))
shards = fimi.shard_db(dense, 4)
params = fimi.FimiParams(variant="reservoir", min_support_rel=0.1,
                         n_db_sample=128, n_fi_sample=64, alpha=0.7,
                         eclat=eclat.EclatConfig(max_out=2048, max_stack=512))
mesh = make_miner_mesh(4)
res = fimi.run(shards, 16, params, jax.random.PRNGKey(2),
               spmd=fimi.shard_map_spmd, mesh=mesh, materialize=True)
assert res.fi_dict == oracle, "shard_map result != oracle"
res_v = fimi.run(shards, 16, params, jax.random.PRNGKey(2), materialize=True)
assert res_v.fi_dict == oracle, "vmap result != oracle"
print("SHARD_MAP_PARITY_OK", len(oracle))
"""


def test_shard_map_parity_subprocess():
    """The same SPMD phase code runs on 4 real devices via shard_map and
    produces the exact FI set (device-count flag isolated in a subprocess)."""
    r = subprocess.run(
        [sys.executable, "-c", _PARITY_SCRIPT],
        capture_output=True, text=True, timeout=900,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
        cwd=str(__import__("pathlib").Path(__file__).resolve().parents[1]),
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "SHARD_MAP_PARITY_OK" in r.stdout
