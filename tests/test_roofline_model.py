"""Validate the analytic roofline cost model against XLA.

1. XLA's ``cost_analysis()`` counts while-loop bodies ONCE (documented
   behaviour this framework relies on — if it ever changes, the roofline
   pipeline must be revisited, so we pin it).
2. The analytic forward-FLOPs model in benchmarks/roofline.py matches XLA's
   cost analysis of the same forward *unrolled* (no scan, no remat) within
   10% on a small dense config — the calibration that justifies using the
   analytic model for the scanned production cells.
"""
import sys
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks import roofline  # noqa: E402
from repro.configs.base import ModelConfig, ShapeConfig  # noqa: E402
from repro.distributed.hlo import normalize_cost_analysis  # noqa: E402
from repro.models import model as M  # noqa: E402


def _flops(compiled) -> float:
    return normalize_cost_analysis(compiled.cost_analysis())["flops"]


def test_cost_analysis_counts_loop_bodies_once():
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)

    def one(w):
        return w @ w

    def scanned(w):
        out, _ = jax.lax.scan(lambda c, _: (c @ w, None), w, None, length=10)
        return out

    f1 = _flops(jax.jit(one).lower(w).compile())
    f10 = _flops(jax.jit(scanned).lower(w).compile())
    assert f1 == f10  # the pinned behaviour


def test_analytic_fwd_flops_matches_unrolled_xla():
    cfg = ModelConfig(
        name="calib", family="dense", n_layers=3, d_model=128, n_heads=4,
        n_kv_heads=2, d_ff=384, vocab=512, remat="none", param_dtype="float32",
        compute_dtype="float32",
    )
    shape = ShapeConfig("calib", seq_len=64, global_batch=4, kind="prefill")

    # unroll: stack of 1-layer scans == analytic sum since bodies count once
    # per distinct layer when n_layers==1; compile a 1-layer model and scale.
    cfg1 = ModelConfig(**{**cfg.__dict__, "n_layers": 1})
    params1 = jax.eval_shape(lambda: M.abstract(cfg1))
    batch = {"tokens": jax.ShapeDtypeStruct((4, 64), jnp.int32)}

    def fwd1(p, b):
        return M.forward(cfg1, p, b)

    c1 = jax.jit(fwd1).lower(M.abstract(cfg1), batch).compile()
    xla1 = _flops(c1)

    cfg0 = ModelConfig(**{**cfg.__dict__, "n_layers": 1, "d_ff": 384})
    # layer cost = flops(1 layer) - flops(embedding+logits); estimate the
    # overhead from the analytic model's logits term.
    T = 4 * 64
    logits_flops = 2.0 * T * cfg.d_model * cfg.vocab_padded
    layer_xla = xla1 - logits_flops

    analytic_total = roofline.fwd_flops(cfg, shape)
    analytic_layers = analytic_total - logits_flops
    analytic_layer = analytic_layers / cfg.n_layers

    rel = abs(layer_xla - analytic_layer) / analytic_layer
    assert rel < 0.10, (layer_xla, analytic_layer, rel)


def test_roofline_table_generates():
    rows = roofline.full_table("single")
    assert len(rows) >= 32
    for c in rows:
        assert c.compute_s > 0 and c.memory_s > 0
        assert c.dominant in ("compute", "memory", "collective")
        assert 0 < c.useful_ratio <= 1.5  # 6ND vs executed (remat ⇒ < 1)


def test_model_flops_moe_active():
    from repro.configs.registry import get_config
    from repro.configs.base import SHAPES

    dense = get_config("llama3.2-3b")
    moe = get_config("olmoe-1b-7b")
    sh = SHAPES["train_4k"]
    # olmoe: active ≈ 1.3B of 6.9B total → MODEL_FLOPS must reflect active
    mf = roofline.model_flops(moe, sh)
    total_params = M.n_params(moe)
    ratio = mf / (6 * total_params * sh.global_batch * sh.seq_len)
    assert ratio < 0.45, ratio
