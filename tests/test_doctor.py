"""The performance doctor (repro.obs.doctor): golden finding sets over the
two committed fixture run records, each rule's trigger on synthetic
snapshots, severity ranking, the renderers, and the obs_report doctor /
critpath CLI exit-code contract (--gate)."""
import json
from pathlib import Path

import pytest

from repro.launch import obs_report
from repro.obs import doctor, runlog

REPO = Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tests" / "data"
HEALTHY = str(FIXTURES / "run_healthy")
SKEWED = str(FIXTURES / "run_skewed_cluster")


def _rules(report):
    return [f["rule"] for f in report["findings"]]


def _snap(gauges=None, counters=None, histograms=None):
    return {"counters": counters or {}, "gauges": gauges or {},
            "histograms": histograms or {}}


# ---------------------------------------------------------------------------
# Golden fixtures: the exact finding sets are part of the contract —
# a rule change must show up here as a reviewable diff.
# ---------------------------------------------------------------------------


def test_healthy_fixture_exact_findings():
    report = doctor.diagnose(runlog.load_run(HEALTHY))
    assert _rules(report) == [
        "cluster-imbalance", "healthy", "thm61-estimation-error",
    ]
    assert report["worst"] == "info"
    assert all(f["severity"] == "info" for f in report["findings"])
    # both analysis digests ride along, self-contained
    assert report["critpath"]["table"][0]["name"] == "cluster/mine"
    assert report["waterfall"]["additivity_err"] < 0.05
    assert report["waterfall"]["measured_x"] == pytest.approx(200 / 106)
    # the Thm 6.1 finding is keyed to the paper's own gauges
    thm = next(f for f in report["findings"]
               if f["rule"] == "thm61-estimation-error")
    assert "cluster/load/estimation_error" in thm["evidence"]
    assert "cluster/shard0/est_load" in thm["evidence"]


def test_skewed_fixture_exact_findings():
    report = doctor.diagnose(runlog.load_run(SKEWED))
    assert _rules(report) == [
        "rebalance-not-engaging", "cluster-imbalance",
        "thm61-estimation-error",
    ]
    sev = {f["rule"]: f["severity"] for f in report["findings"]}
    assert sev == {"rebalance-not-engaging": "error",
                   "cluster-imbalance": "warn",
                   "thm61-estimation-error": "info"}
    assert report["worst"] == "error"
    imb = next(f for f in report["findings"]
               if f["rule"] == "cluster-imbalance")
    assert "dominant" in imb["title"]
    assert imb["evidence"]["cluster/imbalance"] == 2.0
    reb = next(f for f in report["findings"]
               if f["rule"] == "rebalance-not-engaging")
    assert reb["evidence"]["cluster/donations"] == 0
    # the waterfall blames imbalance for > half the gap, estimation for none
    terms = {t["name"]: t for t in report["waterfall"]["terms"]}
    assert terms["imbalance"]["loss_x"] > \
        0.5 * report["waterfall"]["gap_x"]
    assert terms["estimation"]["loss_x"] == pytest.approx(0.0)


# ---------------------------------------------------------------------------
# Individual rules on synthetic run dicts
# ---------------------------------------------------------------------------


def test_non_cluster_run_is_healthy():
    report = doctor.diagnose({"manifest": {}, "metrics": _snap(
        {"fimi/n_fis": 42.0})})
    assert _rules(report) == ["healthy"]
    assert report["critpath"] is None and report["waterfall"] is None


def test_prefetch_stall_warns_then_escalates_on_the_critical_path():
    hist = {"store/prefetch_stall_s": {
        "count": 10, "sum": 0.3, "mean": 0.03, "min": 0.01, "max": 0.06,
        "p50": 0.03, "p95": 0.05, "p99": 0.06}}
    run = {"manifest": {}, "metrics": _snap(histograms=hist)}
    report = doctor.diagnose(run)
    f = next(f for f in report["findings"] if f["rule"] == "prefetch-stall")
    assert f["severity"] == "warn"          # no trace: can't see the path
    assert f["evidence"]["store/prefetch_stall_s.p95"] == 0.05
    assert "--budget-blocks" in f["remediation"]

    # same stalls, but store reads sit on the critical path → error
    run["trace"] = {"traceEvents": [
        {"ph": "X", "name": "store/read_block", "pid": 0, "tid": 7,
         "ts": 0, "dur": 50_000, "args": {}},
    ]}
    report = doctor.diagnose(run)
    f = next(f for f in report["findings"] if f["rule"] == "prefetch-stall")
    assert f["severity"] == "error"
    assert "critical path" in f["title"]


def test_prefetch_stall_quiet_below_threshold():
    hist = {"store/prefetch_stall_s": {
        "count": 10, "sum": 0.001, "mean": 1e-4, "min": 0.0, "max": 2e-3,
        "p50": 1e-4, "p95": 2e-3, "p99": 2e-3}}
    report = doctor.diagnose({"manifest": {}, "metrics": _snap(
        histograms=hist)})
    assert "prefetch-stall" not in _rules(report)


def test_retry_rules():
    r = doctor.diagnose({"manifest": {}, "metrics": _snap(
        counters={"store/retry/exhausted": 2, "store/retry/attempts": 9})})
    f = next(f for f in r["findings"] if f["rule"] == "retry-exhausted")
    assert f["severity"] == "error" and r["worst"] == "error"
    r = doctor.diagnose({"manifest": {}, "metrics": _snap(
        counters={"store/retry/retried_errors": 3})})
    f = next(f for f in r["findings"] if f["rule"] == "retry-exhausted")
    assert f["severity"] == "warn"


def test_capacity_overflow_rule():
    r = doctor.diagnose({"manifest": {}, "metrics": _snap(
        counters={"fimi/exchange_overflow": 5})})
    f = next(f for f in r["findings"] if f["rule"] == "capacity-overflow")
    assert f["severity"] == "error"
    assert f["evidence"] == {"fimi/exchange_overflow": 5}


def test_serve_rules():
    r = doctor.diagnose({"manifest": {}, "metrics": _snap(
        counters={"service/errors": 1, "service/shed": 7},
        histograms={"service/latency_ms": {
            "count": 100, "sum": 500, "mean": 5, "min": 1, "max": 40,
            "p50": 4, "p95": 20, "p99": 35}})})
    rules = _rules(r)
    assert "service-errors" in rules and "service-shed" in rules
    shed = next(f for f in r["findings"] if f["rule"] == "service-shed")
    assert shed["evidence"]["service/latency_ms.p95"] == 20


def test_trace_truncated_rule_scales_severity():
    r = doctor.diagnose({"manifest": {}, "metrics": _snap(
        counters={"trace/dropped_events": 100})})
    f = next(f for f in r["findings"] if f["rule"] == "trace-truncated")
    assert f["severity"] == "info"
    r = doctor.diagnose({"manifest": {}, "metrics": _snap(
        counters={"trace/dropped_events": 50_000})})
    f = next(f for f in r["findings"] if f["rule"] == "trace-truncated")
    assert f["severity"] == "warn"


def test_roofline_regression_needs_history():
    snap = _snap({"kernels/phase4/achieved_frac": 0.4})
    run = {"manifest": {}, "metrics": snap}
    # no history / too little history: the rule stays silent
    assert "roofline-regression" not in _rules(doctor.diagnose(run))
    short = [{"suite": "kernels", "keys": {"phase4_achieved_frac": 0.8}}] * 2
    assert "roofline-regression" not in _rules(
        doctor.diagnose(run, history_rows=short))
    hist = [{"suite": "kernels", "keys": {"phase4_achieved_frac": v}}
            for v in (0.78, 0.80, 0.82, 0.79)]
    r = doctor.diagnose(run, history_rows=hist)
    f = next(f for f in r["findings"] if f["rule"] == "roofline-regression")
    assert f["severity"] == "warn"
    assert f["evidence"]["kernels/phase4/achieved_frac"] == 0.4
    # at the trailing median: no finding
    snap["gauges"]["kernels/phase4/achieved_frac"] = 0.80
    assert "roofline-regression" not in _rules(
        doctor.diagnose(run, history_rows=hist))


def test_thresholds_are_tunable():
    # the healthy fixture's 1.0 imbalance warns under a paranoid threshold
    th = doctor.Thresholds(imbalance_warn=0.5)
    report = doctor.diagnose(runlog.load_run(HEALTHY), thresholds=th)
    f = next(f for f in report["findings"]
             if f["rule"] == "cluster-imbalance")
    assert f["severity"] == "warn"
    assert "healthy" not in _rules(report)


def test_worst_severity_and_ordering():
    assert doctor.worst_severity([]) == "info"
    f = [doctor.Finding("a", "warn", "", "", {}, ""),
         doctor.Finding("b", "error", "", "", {}, "")]
    assert doctor.worst_severity(f) == "error"


# ---------------------------------------------------------------------------
# Renderers
# ---------------------------------------------------------------------------


def test_render_text_and_markdown():
    report = doctor.diagnose(runlog.load_run(SKEWED))
    txt = doctor.render_text(report)
    assert "critical path" in txt and "speedup waterfall" in txt
    assert "worst = error" in txt
    assert "rebalance-not-engaging" in txt
    assert "evidence:" in txt and "fix:" in txt
    md = doctor.render_markdown(report)
    assert md.startswith("## Performance doctor")
    assert "| sev | rule | finding | remediation |" in md
    assert "### Critical path" in md and "### Speedup waterfall" in md
    assert "`rebalance-not-engaging`" in md


# ---------------------------------------------------------------------------
# CLI: obs_report doctor / critpath
# ---------------------------------------------------------------------------


def test_cli_doctor_gate_exit_codes(tmp_path, capsys):
    no_hist = str(tmp_path / "no_history.jsonl")
    assert obs_report.main(
        ["doctor", HEALTHY, "--history", no_hist, "--gate"]) == 0
    capsys.readouterr()
    assert obs_report.main(
        ["doctor", SKEWED, "--history", no_hist, "--gate"]) == 1
    err = capsys.readouterr().err
    assert "DOCTOR GATE" in err
    # without --gate even an error-severity report exits 0 (report-only)
    assert obs_report.main(["doctor", SKEWED, "--history", no_hist]) == 0


def test_cli_doctor_format_json_and_markdown(tmp_path, capsys):
    no_hist = str(tmp_path / "no_history.jsonl")
    assert obs_report.main(
        ["doctor", SKEWED, "--history", no_hist, "--format", "json"]) == 0
    report = json.loads(capsys.readouterr().out)
    assert report["worst"] == "error"
    assert [f["rule"] for f in report["findings"]][0] == \
        "rebalance-not-engaging"
    assert obs_report.main(
        ["doctor", HEALTHY, "--history", no_hist, "--format",
         "markdown"]) == 0
    assert "## Performance doctor" in capsys.readouterr().out


def test_cli_critpath(tmp_path, capsys):
    assert obs_report.main(["critpath", HEALTHY]) == 0
    out = capsys.readouterr().out
    assert "cluster/mine" in out and "shard0" in out
    assert obs_report.main(["critpath", HEALTHY, "--format", "json"]) == 0
    cp = json.loads(capsys.readouterr().out)
    assert cp["table"][0]["name"] == "cluster/mine"
    assert obs_report.main(["critpath", HEALTHY, "--path"]) == 0
    assert "pre-order" in capsys.readouterr().out
    # a record without a trace exits 2, like other unusable inputs
    bare = tmp_path / "bare"
    bare.mkdir()
    (bare / "manifest.json").write_text(json.dumps(
        {"name": "x", "config": {}}))
    assert obs_report.main(["critpath", str(bare)]) == 2
