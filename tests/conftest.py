import numpy as np
import pytest

# NOTE: no XLA_FLAGS here by design — smoke tests and benches must see ONE
# device (the dry-run sets its own 512-device flag in its own process).


@pytest.fixture(scope="session")
def thesis_db():
    """The thesis' running example (Example 8.1), items shifted to 0-based."""
    import jax.numpy as jnp

    from repro.core import bitmap as bm

    tx = [
        {1, 2, 3, 4, 6}, {3, 5, 6}, {1, 3, 4}, {1, 2, 6}, {1, 3, 4, 5, 6},
        {1, 2, 3, 4, 5}, {2, 3, 4, 5}, {2, 3, 4, 5}, {3, 4, 5, 6}, {2, 4, 5},
        {1, 2, 4, 5}, {2, 3, 4, 5, 6}, {3, 4, 5, 6}, {4, 5, 6}, {1, 3, 4, 5, 6},
    ]
    return bm.BitmapDB.from_transactions([[i - 1 for i in t] for t in tx], 6)


@pytest.fixture(scope="session")
def small_db():
    """A 512-tx synthetic IBM-style DB (dense + BitmapDB + oracle at 8%)."""
    import jax.numpy as jnp

    from repro.core import bitmap as bm
    from repro.core import eclat
    from repro.data.ibm_gen import IBMParams, generate_dense

    dense = generate_dense(
        IBMParams(n_tx=512, n_items=24, n_patterns=8, avg_pattern_len=5,
                  avg_tx_len=8, seed=3)
    )
    db = bm.BitmapDB.from_dense(jnp.asarray(dense))
    minsup = int(np.ceil(0.08 * 512))
    oracle = eclat.brute_force_fis(dense, minsup)
    return dense, db, minsup, oracle
