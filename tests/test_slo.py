"""Sliding-window SLO engine: windowed histogram percentiles vs the numpy
nearest-rank oracle over rotating windows, windowed counter rates, and the
burn-rate / latency alert state machines (hysteresis, exactly-once
transitions, min-request noise guard) — all on a fake clock."""
import math

import numpy as np
import pytest

from repro.obs.slo import (
    SLOPolicy,
    SLOTracker,
    WindowedCounter,
    WindowedHistogram,
)

#: documented histogram error bound: bucket midpoint within sqrt(growth)
FACTOR = math.sqrt(1.08) * (1 + 1e-9)


class FakeClock:
    def __init__(self, t: float = 100.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def _oracle(samples, q):
    return float(np.percentile(np.asarray(samples, float), q,
                               method="nearest"))


def _assert_close_percentiles(wh, samples):
    for q in (50, 95, 99):
        want = _oracle(samples, q)
        got = wh.percentile(q)
        assert got is not None
        assert want / FACTOR <= got <= want * FACTOR, (
            f"q={q}: got {got}, oracle {want} over {len(samples)} live"
        )


# ---------------------------------------------------------------------------
# WindowedHistogram: the merged ring vs numpy over exactly the live samples
# ---------------------------------------------------------------------------


def test_windowed_histogram_matches_numpy_while_rotating():
    clk = FakeClock()
    wh = WindowedHistogram("t/lat", window_s=30.0, slots=6, clock=clk)
    rotate = 30.0 / 6
    rng = np.random.default_rng(3)
    live = []  # (absolute slot k, value)
    # 18 rotations: every sample's slot index is floor(elapsed / rotate)
    for k in range(18):
        for v in rng.lognormal(0.0, 1.5, size=40):
            wh.record(float(v))
            live.append((k, float(v)))
        # the live window covers slots (k-5 .. k): older cells were cleared
        window = [v for kk, v in live if kk > k - 6]
        assert wh.count == len(window)
        _assert_close_percentiles(wh, window)
        clk.advance(rotate)


def test_windowed_histogram_empty_and_expiry():
    clk = FakeClock()
    wh = WindowedHistogram("t/lat", window_s=10.0, slots=5, clock=clk)
    assert wh.count == 0 and wh.percentile(99) is None
    s = wh.summary()
    assert s["count"] == 0 and s["p99"] is None
    wh.record(7.0)
    assert wh.count == 1 and wh.percentile(50) == 7.0
    # a gap longer than the window leaves the ring empty again
    clk.advance(10.0)
    assert wh.count == 0 and wh.percentile(50) is None


def test_windowed_histogram_burst_in_one_rotation():
    """A burst confined to one slot survives exactly until its cell expires:
    present through window_s - rotate_s, gone at window_s."""
    clk = FakeClock()
    wh = WindowedHistogram("t/lat", window_s=12.0, slots=4, clock=clk)
    for _ in range(100):
        wh.record(50.0)
    clk.advance(12.0 - 3.0)        # burst cell is the oldest live slot
    assert wh.count == 100
    assert wh.percentile(99) == 50.0
    clk.advance(3.0)               # now a full window has elapsed
    assert wh.count == 0


def test_windowed_counter_value_and_rate():
    clk = FakeClock()
    wc = WindowedCounter("t/served", window_s=10.0, slots=5, clock=clk)
    assert wc.value == 0 and wc.rate() == 0.0
    wc.inc(20)
    clk.advance(2.0)
    wc.inc(10)
    # coverage ramps with elapsed time until it saturates at window_s
    assert wc.value == 30
    assert wc.rate() == pytest.approx(30 / 2.0)
    clk.advance(8.0)               # first cell (t=0) just expired
    assert wc.value == 10
    assert wc.rate() == pytest.approx(10 / 10.0)
    clk.advance(10.0)
    assert wc.value == 0


# ---------------------------------------------------------------------------
# SLOTracker: burn-rate + latency alerts, hysteresis, exactly-once edges
# ---------------------------------------------------------------------------


def _policy(**kw):
    base = dict(p99_ms=50.0, availability=0.9, window_s=30.0, slots=6,
                burn_hi=2.0, burn_lo=1.0, latency_clear=0.8,
                min_requests=10)
    base.update(kw)
    return SLOPolicy(**base)


def test_burn_rate_alert_fires_once_and_clears_with_hysteresis():
    clk = FakeClock()
    slo = SLOTracker(_policy(), clock=clk)
    fired = []
    slo.on_alert(fired.append)

    # healthy window: budget 0.1, zero bad -> burn 0
    for _ in range(40):
        slo.record_ok(5.0)
    st = slo.evaluate()
    assert st.burn_rate == 0.0 and st.availability_ok and not st.events

    # 30% shed -> burn 3.0 >= burn_hi 2.0: fires exactly once
    for _ in range(18):
        slo.record_shed()
    st = slo.evaluate()
    assert st.burn_rate == pytest.approx((18 / 58) / 0.1)
    assert st.alert_active and not st.availability_ok
    assert [e["kind"] for e in st.events] == ["slo_alert"]
    assert st.events[0]["objective"] == "availability"
    assert slo.evaluate().events == []          # edge, not level

    # hover in the hysteresis band (burn_lo <= burn < burn_hi): no flap
    clk.advance(31.0)                           # drain the window
    for _ in range(85):
        slo.record_ok(5.0)
    for _ in range(15):
        slo.record_shed()                       # burn 1.5
    st = slo.evaluate()
    assert 1.0 <= st.burn_rate < 2.0
    assert st.alert_active and st.events == []

    # drop below burn_lo: clears exactly once
    clk.advance(31.0)
    for _ in range(50):
        slo.record_ok(5.0)
    st = slo.evaluate()
    assert not st.alert_active
    assert [e["kind"] for e in st.events] == ["slo_clear"]
    assert slo.evaluate().events == []
    assert [e["kind"] for e in fired] == ["slo_alert", "slo_clear"]


def test_latency_alert_hysteresis():
    clk = FakeClock()
    slo = SLOTracker(_policy(), clock=clk)      # objective p99 50ms
    for _ in range(30):
        slo.record_ok(100.0)                    # constant -> p99 exactly 100
    st = slo.evaluate()
    assert not st.latency_ok and st.alert_active
    assert [(e["kind"], e["objective"]) for e in st.events] == [
        ("slo_alert", "latency")]

    # between clear (40ms) and objective (50ms): stays active, no re-fire
    clk.advance(31.0)
    for _ in range(30):
        slo.record_ok(45.0)
    st = slo.evaluate()
    assert st.latency_ok and st.alert_active and st.events == []

    # below latency_clear * objective: clears
    clk.advance(31.0)
    for _ in range(30):
        slo.record_ok(10.0)
    st = slo.evaluate()
    assert not st.alert_active
    assert [e["kind"] for e in st.events] == ["slo_clear"]


def test_min_requests_guards_noise():
    clk = FakeClock()
    slo = SLOTracker(_policy(min_requests=10), clock=clk)
    for _ in range(3):
        slo.record_shed()                       # 100% bad, but only 3 reqs
    st = slo.evaluate()
    assert st.burn_rate > 2.0                   # the ratio itself is huge...
    assert st.availability_ok and not st.alert_active and not st.events


def test_errors_count_against_budget_and_status_rates():
    clk = FakeClock()
    slo = SLOTracker(_policy(), clock=clk)
    clk.advance(10.0)                           # coverage = 10s
    for _ in range(80):
        slo.record_ok(5.0)
    for _ in range(10):
        slo.record_error()
    for _ in range(10):
        slo.record_shed()
    st = slo.evaluate()
    assert (st.total, st.served, st.shed, st.errors) == (100, 80, 10, 10)
    assert st.shed_rate == pytest.approx(0.2)
    assert st.burn_rate == pytest.approx(2.0)
    assert st.qps == pytest.approx(8.0)
    assert st.offered_qps == pytest.approx(10.0)
    assert not st.availability_ok               # burn at burn_hi fires


def test_alerts_since_filters_fires_by_time():
    clk = FakeClock()
    slo = SLOTracker(_policy(), clock=clk)
    for _ in range(20):
        slo.record_shed()
    slo.evaluate()                              # fire at t=100
    t_fire = clk.t
    clk.advance(31.0)
    for _ in range(50):
        slo.record_ok(1.0)
    slo.evaluate()                              # clear at t=131
    assert len(slo.alerts) == 2
    assert [e["kind"] for e in slo.alerts_since(0.0)] == ["slo_alert"]
    assert slo.alerts_since(t_fire + 1.0) == []
